"""Template-vectorized synthesis + incremental frontier packing (PR 3).

Record-level parity against the scalar expert system, symbolic-breakdown
schema conformance, incremental packing/splicing equivalence, and the
hill-climb/beam seen-set.
"""
import inspect

import numpy as np
import pytest

from repro.core import (autocomplete, batchcost, elements as el, synthesis,
                        templatecost, whatif)
from repro.core.autocomplete import (design_beam, design_hillclimb,
                                     design_neighbors, default_candidates,
                                     default_terminals,
                                     enumerate_completions)
from repro.core.batchcost import (compile_breakdown, concat_frontiers,
                                  cost_many, pack_frontier)
from repro.core.devicecost import model_id
from repro.core.synthesis import Workload, cost_workload

OPS = ("get", "range_get", "update", "bulk_load")


def _grid_specs():
    specs = []
    for name, make in sorted(el.ALL_PAPER_SPECS.items()):
        sig = inspect.signature(make)
        specs.append(make(10_000) if "n_puts" in sig.parameters else make())
    return specs


WORKLOADS = [
    Workload(n_entries=10_000),
    Workload(n_entries=250_000, zipf_alpha=1.5),
    Workload(n_entries=1_000_000, selectivity=0.01, n_queries=1000),
]


@pytest.mark.parametrize("workload", WORKLOADS,
                         ids=["uniform", "zipf", "ranges"])
@pytest.mark.parametrize("op", OPS)
def test_vectorized_records_match_scalar_synthesis(workload, op):
    """The strongest parity contract: for every paper spec the vectorized
    packer must emit the *same records* as the scalar pipeline — identical
    model-id sequence, sizes/counts to 1e-12 — once count-0 rows (records
    the scalar walker skips) and tile pads are dropped."""
    specs = _grid_specs()
    segs = templatecost.pack_specs([s.chain for s in specs], workload,
                                   ((op, 1.0),))
    for spec, (ids, sizes, weights) in zip(specs, segs):
        comp = compile_breakdown(
            synthesis.synthesize_operation(op, spec, workload))
        m = weights != 0.0
        assert np.array_equal(ids[m], comp.model_ids), (spec.name, op)
        np.testing.assert_allclose(sizes[m], comp.sizes, rtol=1e-12)
        np.testing.assert_allclose(weights[m], comp.counts, rtol=1e-12)


@pytest.mark.parametrize("op", OPS)
def test_emission_matches_symbolic_breakdown(op):
    """Each chain's emitted layout (count-0 slots included) must equal the
    symbolic record schema synthesis.py declares for its template."""
    w = Workload(n_entries=77_000)
    specs = _grid_specs()
    segs = templatecost.pack_specs([s.chain for s in specs], w,
                                   ((op, 1.0),))
    for spec, (ids, _, _) in zip(specs, segs):
        geom = templatecost.chain_geometry(spec.chain, w)
        schema = synthesis.symbolic_breakdown(op, geom.template)
        assert np.array_equal(ids[:len(schema)],
                              [model_id(l2) for _, l2 in schema]), spec.name


def test_chains_share_templates_across_parameters():
    """The point of template grouping: parameter mutations (fanout and
    capacity doublings — the hill-climb neighborhood) and sibling elements
    taking the same synthesis branches (B+ vs CSB+) share one template and
    therefore one symbolic breakdown."""
    w = Workload(n_entries=1_000_000)
    t = lambda spec: templatecost.chain_geometry(spec.chain, w).template
    assert t(el.spec_btree(fanout=20)) == t(el.spec_btree(fanout=21))
    assert t(el.spec_btree()) == t(el.spec_csb_tree())
    assert t(el.spec_btree(page=256)) == t(el.spec_btree(page=512))
    # different branch classes are different templates
    assert t(el.spec_btree()) != t(el.spec_hash_table())
    # recursion depth changes the expanded level sequence
    assert t(el.spec_btree(fanout=20)) != t(el.spec_btree(fanout=2))


def test_statics_workload_independent():
    """Element statics (node bytes included) are cached per element value
    across workloads — the record-parity grid above would catch a workload
    dependence sneaking into _node_bytes."""
    e = el.btree_internal(20)
    st = templatecost.statics_of(e)
    assert templatecost.statics_of(el.btree_internal(20)) is st
    assert templatecost.statics_of(el.btree_internal(40)) is not st


def test_concat_frontiers_matches_from_scratch_pack(hw_analytical):
    """Splicing retained frontiers must score identically (bit-for-bit
    segments, only design numbering shifts) to packing the concatenated
    spec list from scratch."""
    w = Workload(n_entries=300_000)
    mix = {"get": 10.0, "update": 5.0}
    a = [el.spec_btree(), el.spec_hash_table()]
    b = [el.spec_skip_list(), el.spec_trie(), el.spec_btree(fanout=40)]
    spliced = concat_frontiers([pack_frontier(a, w, mix),
                                pack_frontier(b, w, mix)])
    scratch = pack_frontier(a + b, w, mix)
    assert spliced.n_segments == scratch.n_segments == len(a) + len(b)
    np.testing.assert_array_equal(spliced.ids, scratch.ids)
    np.testing.assert_array_equal(spliced.sizes, scratch.sizes)
    np.testing.assert_array_equal(spliced.weights, scratch.weights)
    np.testing.assert_array_equal(spliced.tile_segments,
                                  scratch.tile_segments)
    for engine, rtol in (("grouped", 1e-9), ("fused", 1e-6)):
        sp = spliced.score(hw_analytical, engine=engine)
        sc = scratch.score(hw_analytical, engine=engine)
        np.testing.assert_allclose(sp, sc, rtol=rtol)
        assert int(np.argmin(sp)) == int(np.argmin(sc))


def test_incremental_hillclimb_rounds_parity(hw_analytical):
    """Across simulated hill-climb rounds, packing each round's frontier
    with warm segment caches (splicing) must score identically to packing
    the same frontier in a fresh cache state."""
    w = Workload(n_entries=500_000)
    mix = {"get": 60.0, "update": 40.0}
    candidates = default_candidates()
    terminals = default_terminals()
    spec = el.spec_btree()
    batchcost.clear_caches()
    for _ in range(3):
        frontier = design_neighbors(spec.chain, candidates, terminals)
        warm = pack_frontier(frontier, w, mix)
        warm_grouped = warm.score(hw_analytical, engine="grouped")
        warm_fused = warm.score(hw_analytical)
        saved = (batchcost._segment_cache, batchcost._frontier_cache)
        try:
            # fresh caches: everything synthesizes from scratch
            batchcost._segment_cache = batchcost._DictCache(maxsize=65536)
            batchcost._frontier_cache = batchcost._DictCache(maxsize=16)
            cold = pack_frontier(frontier, w, mix)
        finally:
            batchcost._segment_cache, batchcost._frontier_cache = saved
        cold_grouped = cold.score(hw_analytical, engine="grouped")
        np.testing.assert_allclose(warm_grouped, cold_grouped, rtol=1e-9)
        np.testing.assert_allclose(warm_fused, cold_grouped, rtol=1e-6)
        assert int(np.argmin(warm_fused)) == int(np.argmin(warm_grouped))
        spec = frontier[int(np.argmin(warm_grouped))]
    scalar = [cost_workload(s, w, hw_analytical, mix) for s in frontier]
    np.testing.assert_allclose(warm_grouped, scalar, rtol=1e-9)


def test_what_if_design_splice_matches_two_design_pack(hw_analytical):
    """what_if_design splices two independently-packed one-design
    frontiers; the answer must match both the two-design pack and the
    scalar oracle."""
    w = Workload(n_entries=400_000)
    mix = {"get": 20.0}
    base = el.spec_hash_table()
    variant = whatif.add_bloom_filters(base)
    ans = whatif.what_if_design(base, variant, w, hw_analytical, mix)
    both = cost_many([base, variant], w, hw_analytical, mix)
    assert ans.baseline_seconds == pytest.approx(float(both[0]), rel=1e-9)
    assert ans.variant_seconds == pytest.approx(float(both[1]), rel=1e-9)
    scalar = whatif.what_if_design(base, variant, w, hw_analytical, mix,
                                   engine="scalar")
    assert ans.baseline_seconds == pytest.approx(
        scalar.baseline_seconds, rel=1e-6)
    assert ans.variant_seconds == pytest.approx(
        scalar.variant_seconds, rel=1e-6)
    assert ans.beneficial == scalar.beneficial


def test_hillclimb_never_recosts_a_chain(hw_analytical, monkeypatch):
    """The seen-set contract: across all rounds of a climb, no chain
    reaches the costing engine twice, and ``designs_costed`` counts
    exactly the unique designs costed."""
    costed = []
    real = autocomplete.cost_many

    def recording(specs, *args, **kwargs):
        costed.extend(s.chain for s in specs)
        return real(specs, *args, **kwargs)

    monkeypatch.setattr(autocomplete, "cost_many", recording)
    w = Workload(n_entries=200_000)
    result = design_hillclimb(w, hw_analytical, {"get": 60.0, "update": 40.0},
                              max_steps=10)
    assert len(costed) == len(set(costed)), "a chain was costed twice"
    assert result["designs_costed"] == len(costed)
    assert result["designs_costed"] > 1


def test_hillclimb_engines_agree_after_seen_set(hw_analytical):
    w = Workload(n_entries=200_000)
    mix = {"get": 60.0, "update": 40.0}
    f = design_hillclimb(w, hw_analytical, mix, max_steps=10)
    s = design_hillclimb(w, hw_analytical, mix, max_steps=10, batched=False)
    assert (f["design"], f["fanouts"]) == (s["design"], s["fanouts"])
    assert f["cost_s"] == pytest.approx(s["cost_s"], rel=1e-6)
    assert f["designs_costed"] == s["designs_costed"]


def test_design_beam_improves_and_engines_agree(hw_analytical):
    """Beam search must do at least as well as the greedy climb from the
    same start, and its answer must agree across costing engines."""
    w = Workload(n_entries=200_000)
    mix = {"get": 60.0, "update": 40.0}
    climb = design_hillclimb(w, hw_analytical, mix, max_steps=10)
    beam = design_beam(w, hw_analytical, mix, beam_width=4, max_rounds=6)
    assert beam["cost_s"] <= climb["cost_s"] * (1 + 1e-6)
    assert beam["designs_costed"] >= climb["designs_costed"]
    grouped = design_beam(w, hw_analytical, mix, beam_width=4,
                          max_rounds=6, engine="grouped")
    assert beam["cost_s"] == pytest.approx(grouped["cost_s"], rel=1e-6)
    scalar = design_beam(w, hw_analytical, mix, beam_width=4,
                         max_rounds=6, batched=False)
    assert grouped["cost_s"] == pytest.approx(scalar["cost_s"], rel=1e-9)


def test_frontier_cache_serves_repacks_and_bounds_memory(hw_analytical):
    batchcost.clear_caches()
    w = Workload(n_entries=50_000)
    specs = [el.spec_btree(), el.spec_trie()]
    p1 = pack_frontier(specs, w, None)
    assert pack_frontier(specs, w, None) is p1
    # a different mix is a different frontier
    p2 = pack_frontier(specs, w, {"get": 3.0})
    assert p2 is not p1
    info = batchcost.cache_info()
    assert info["frontier"].maxsize is not None  # bounded, evicts oldest


@pytest.mark.slow
def test_large_frontier_template_pack_matches_scalar(hw_analytical):
    """Benchmark-grade frontier (full depth-4 enumeration, >3000 unique
    chains): template-vectorized packing must match the per-design scalar
    path to 1e-9 totals with the identical argmin design."""
    w = Workload(n_entries=1_000_000)
    mix = {"get": 80.0, "update": 20.0}
    frontier = enumerate_completions((), default_candidates(),
                                     default_terminals(), 4, "big")
    batchcost.clear_caches()
    grouped = cost_many(frontier, w, hw_analytical, mix, engine="grouped")
    sample = np.linspace(0, len(frontier) - 1, 37).astype(int)
    scalar = [cost_workload(frontier[i], w, hw_analytical, mix)
              for i in sample]
    np.testing.assert_allclose(grouped[sample], scalar, rtol=1e-9)
    fused = cost_many(frontier, w, hw_analytical, mix)
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)
    assert int(np.argmin(fused)) == int(np.argmin(grouped))
