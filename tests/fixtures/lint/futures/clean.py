"""Clean twin of the futures fixture: bounded waits, real escapes, and a
documented untimed-wait suppression."""


def helper(executor, job):
    return executor.submit(job)


def fan_out(executor, jobs):
    futures = [executor.submit(j) for j in jobs]
    return [f.result(timeout=30.0) for f in futures]


def handoff(executor, job, sink):
    fut = helper(executor, job)
    sink(fut)                               # call-arg escape


def stored(executor, job, registry):
    fut = executor.submit(job)
    registry["job"] = fut                   # container escape


def blocking(executor, job):
    # lint: untimed-wait(fixture demonstrates a documented suppression)
    return executor.submit(job).result()
