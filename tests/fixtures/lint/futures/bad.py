"""Seeded future-hygiene violations (parsed by the analyzer, never run)."""


def helper(executor, job):
    return executor.submit(job)             # future-returning helper


def drop(executor, job):
    executor.submit(job)                    # dropped-future


def forget(executor, job):
    fut = executor.submit(job)              # unawaited-future
    other = 1
    return other


def wait_forever(executor, job):
    fut = helper(executor, job)             # tracked through the helper
    return fut.result()                     # untimed-wait


def chain(executor, job):
    return executor.submit(job).result()    # untimed-wait (chained)
