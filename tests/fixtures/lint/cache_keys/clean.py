"""Clean twin of the cache-keys fixture: hardware/workload stay out of
keys, and the hardware-keyed ``device_banks`` exception is exercised."""
from repro.core.memo import DictCache

PACK_CACHE = DictCache(max_entries=64, name="fixture_pack_clean")
STATICS_CACHE = DictCache(max_entries=64, name="segment_statics")
BANKS = DictCache(max_entries=8, name="device_banks")


def pack_hardware_free(spec, mix, hw):
    key = (spec, mix)
    cached = PACK_CACHE.get(key)
    if cached is None:
        cached = PACK_CACHE.put(key, (spec, mix))
    return cached, hw.stream_bandwidth      # hw used, just not in the key


def statics_by_count(template, n_entries, workload):
    key = (template, n_entries)             # count routed as a parameter
    return STATICS_CACHE.get(key), workload


def banks_for(hw):
    key = (hw.name, hw.n_devices)           # device_banks IS hw-keyed
    return BANKS.get(key)
