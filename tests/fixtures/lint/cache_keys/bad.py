"""Seeded cache-keys violations (parsed by the analyzer, never run)."""
from repro.core.memo import DictCache

PACK_CACHE = DictCache(max_entries=64, name="fixture_pack")
STATICS_CACHE = DictCache(max_entries=64, name="chain_statics")


def pack_with_hardware(spec, hw):
    key = (spec, hw.stream_bandwidth)       # hardware leaks into the key
    cached = PACK_CACHE.get(key)
    if cached is not None:
        return cached
    plan = (spec, hw.stream_bandwidth)
    PACK_CACHE.put(key, plan)
    return plan


def statics_with_workload(template, workload):
    key = (template, len(workload.entries))   # workload leaks into statics
    return STATICS_CACHE.get(key)
