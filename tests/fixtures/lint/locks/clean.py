"""Clean twin of the locks fixture, including a documented suppression."""
from repro.core.memo import MEMO_LOCK, REGISTRY


class DictCache:
    def __init__(self):
        self._data = {}
        self._hits = 0

    def get(self, key):
        with MEMO_LOCK:
            self._hits += 1
            return self._data.get(key)

    def snapshot(self):
        # lint: unlocked(fixture demonstrates a documented suppression)
        return dict(self._data)


def lookup(name):
    with MEMO_LOCK:
        return REGISTRY.get(name)
