"""Seeded lock-discipline violations (parsed by the analyzer, never run)."""
from repro.core.memo import MEMO_LOCK, REGISTRY


class DictCache:
    def __init__(self):
        self._data = {}
        self._hits = 0

    def get(self, key):
        return self._data.get(key)          # unlocked read

    def put(self, key, value):
        with MEMO_LOCK:
            self._data[key] = value
        self._hits += 1                     # unlocked write


def register(name, cache):
    REGISTRY[name] = cache                  # unlocked guarded-global write
