"""A suppression with no reason must itself be reported."""


def probe(pool):
    # lint: unlocked()
    return pool.status
