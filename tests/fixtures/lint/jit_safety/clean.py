"""Clean twin of the jit-safety fixture: shape-metadata branches, static
branches, frozen SCREAMING_CASE constants, hashable statics."""
import jax
import jax.numpy as jnp
import numpy as np

COEFFS = np.array([1.0, 2.0])               # frozen module constant


def _pad(v, mult):
    pad = (-v.shape[0]) % mult
    if pad == 0:                            # shape-derived: static
        return v
    return jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])


def _kernel(x, n, with_knn=False):
    if x.shape[0] == 0:                     # shape metadata branch
        return x
    if with_knn:                            # static-arg branch
        x = x + 1
    y = _pad(x, n)
    y = jnp.where(y > 0, y, 0.0)            # traced select, no branch
    return y * COEFFS[0]


kernel = jax.jit(_kernel, static_argnums=(1, 2))
