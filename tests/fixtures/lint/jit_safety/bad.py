"""Seeded jit-safety violations (parsed by the analyzer, never run)."""
import jax
import numpy as np

scale = np.array([1.0, 2.0])                # mutable-looking module array


def _pad(v):
    return int(v)                           # traced-concretize (via descent)


def _kernel(x, n, flags=[0]):               # unhashable static default
    if x > 0:                               # traced-branch
        x = x + 1
    v = float(x)                            # traced-concretize
    w = _pad(x)                             # descends into _pad
    return x * scale + v + w                # array-closure on `scale`


kernel = jax.jit(_kernel, static_argnums=(1, 2))
