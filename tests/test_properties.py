"""Property-based differential suite over the whole cost pipeline.

Random (design, workload, hardware, mix) triples from
:mod:`repro.testing.strategies` drive four invariants the pipeline
documents but example-based tests only spot-check:

* scalar oracle == grouped engine (1e-9) == fused engine (1e-6) on any
  valid input, not just the paper's named designs;
* ``pack_frontier`` → ``split`` → ``concat_frontiers`` is an identity
  (arrays and scores, bit for bit);
* every cell of a ``cost_sweep`` grid equals the per-point
  ``cost_many`` answer;
* a memo snapshot save/restore round-trip preserves scoring exactly.

Runs deterministically with or without real hypothesis installed (the
fallback in :mod:`repro.testing.hypothesis_fallback` draws from derived
per-example seeds).  On a failure the fallback prints one replay seed;
re-run just that example with ``REPRO_PROPERTY_SEED=<seed>``.
The autouse ``_memo_pollution_guard`` fixture (tests/conftest.py)
cold-starts and drain-checks the global memo layer around every test
here, so cross-example cache pollution cannot mask a parity failure.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.core import batchcost, memo
from repro.core.synthesis import cost_workload
from repro.testing.strategies import (design_specs, given, hardware_profiles,
                                      mixes, settings, st, workloads)

pytestmark = pytest.mark.properties

#: every invariant must clear the issue's bar of >= 50 random examples
EXAMPLES = 50


# ---------------------------------------------------------------------------
# Invariant 1: three engines, one answer.
# ---------------------------------------------------------------------------
@settings(max_examples=EXAMPLES, deadline=None)
@given(design_specs(), workloads(), mixes(), hardware_profiles())
def test_engine_parity(spec, workload, mix, hw):
    """fused == grouped == scalar oracle on random valid triples."""
    scalar = cost_workload(spec, workload, hw, mix)
    grouped = float(batchcost.cost_many(
        [spec], workload, hw, mix, engine="grouped")[0])
    fused = float(batchcost.cost_many(
        [spec], workload, hw, mix, engine="fused")[0])
    assert scalar > 0.0
    np.testing.assert_allclose(grouped, scalar, rtol=1e-9)
    np.testing.assert_allclose(fused, scalar, rtol=1e-6)


# ---------------------------------------------------------------------------
# Invariant 2: pack -> split -> concat is an identity.
# ---------------------------------------------------------------------------
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.lists(design_specs(), min_size=1, max_size=6),
       st.integers(min_value=1, max_value=8),
       workloads(), mixes(), hardware_profiles())
def test_frontier_split_concat_roundtrip(specs, n_parts, workload, mix, hw):
    """Splitting a packed frontier and splicing the parts back together
    reproduces the original record arrays and scores bit for bit."""
    frontier = batchcost.pack_frontier(specs, workload, mix)
    parts = frontier.split(n_parts)
    spliced = batchcost.concat_frontiers(parts)
    assert spliced.n_segments == frontier.n_segments
    np.testing.assert_array_equal(spliced.ids, frontier.ids)
    np.testing.assert_array_equal(spliced.sizes, frontier.sizes)
    np.testing.assert_array_equal(spliced.weights, frontier.weights)
    np.testing.assert_array_equal(spliced.tile_segments,
                                  frontier.tile_segments)
    np.testing.assert_array_equal(spliced.score(hw), frontier.score(hw))
    # the parts themselves tile the whole: stacked scores == whole score
    stacked = np.concatenate([p.score(hw) for p in parts])
    np.testing.assert_array_equal(stacked, frontier.score(hw))


# ---------------------------------------------------------------------------
# Invariant 3: a sweep grid is exactly its per-point columns.
# ---------------------------------------------------------------------------
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.lists(design_specs(), min_size=1, max_size=4),
       st.lists(workloads(), min_size=1, max_size=3),
       mixes(), hardware_profiles())
def test_sweep_grid_matches_cost_many(specs, wls, mix, hw):
    """Every ``cost_sweep`` cell equals the per-point ``cost_many``
    answer: bit-identical on the grouped engine, and within the
    documented 1e-6 of the scalar-parity contract on the fused engine."""
    grid_grouped = batchcost.cost_sweep(specs, wls, hw, mix,
                                        engine="grouped")
    grid_fused = batchcost.cost_sweep(specs, wls, hw, mix, engine="fused")
    assert grid_grouped.shape == (len(wls), len(specs))
    for i, w in enumerate(wls):
        per_point = batchcost.cost_many(specs, w, hw, mix,
                                        engine="grouped")
        np.testing.assert_array_equal(grid_grouped[i], per_point)
        np.testing.assert_allclose(grid_fused[i], per_point, rtol=1e-6)


# ---------------------------------------------------------------------------
# Invariant 4: memo snapshots restore with full fidelity.
# ---------------------------------------------------------------------------
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.lists(design_specs(), min_size=1, max_size=4),
       workloads(), mixes(), hardware_profiles())
def test_memo_snapshot_roundtrip(specs, workload, mix, hw):
    """snapshot -> clear -> restore preserves warm-path scoring exactly
    (and the restore lands entries back in the caches it drained)."""
    cold = batchcost.cost_many(specs, workload, hw, mix, engine="fused")
    fd, path = tempfile.mkstemp(suffix=".memo")
    os.close(fd)
    try:
        written = memo.snapshot_caches(path)
        assert written > 0            # packing populated snapshot caches
        batchcost.clear_caches()
        report = memo.restore_caches_report(path)
        assert report.outcome == "restored"
        assert report.entries == written
        warm = batchcost.cost_many(specs, workload, hw, mix,
                                   engine="fused")
        np.testing.assert_array_equal(warm, cold)
    finally:
        os.unlink(path)
