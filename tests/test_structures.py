"""Ground-truth data structure implementations (paper §5 baselines):
behavioural equivalence against a dict oracle, incl. hypothesis sweeps."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sweeps
    from repro.testing.hypothesis_fallback import (
        given, settings, strategies as st)

from repro.core import structures as S


def _build(cls, rng, n=2000):
    keys = rng.choice(np.arange(n * 4), size=n, replace=False).astype(np.int64)
    values = rng.integers(0, 1 << 30, size=n).astype(np.int64)
    s = cls()
    s.bulk_load(keys, values)
    return s, dict(zip(keys.tolist(), values.tolist()))


@pytest.mark.parametrize("name", sorted(S.ALL_STRUCTURES))
def test_get_matches_oracle(name, rng):
    s, oracle = _build(S.ALL_STRUCTURES[name], rng)
    keys = list(oracle)
    for key in keys[:50]:
        assert s.get(key) == oracle[key], name
    for miss in range(10**7, 10**7 + 20):
        assert s.get(miss) is None, name


@pytest.mark.parametrize("name", sorted(S.ALL_STRUCTURES))
def test_range_get_matches_oracle(name, rng):
    s, oracle = _build(S.ALL_STRUCTURES[name], rng)
    for lo in (0, 1000, 5000):
        hi = lo + 1500
        want = sorted(v for k, v in oracle.items() if lo <= k < hi)
        got = sorted(s.range_get(lo, hi))
        assert got == want, name


@pytest.mark.parametrize("name", sorted(S.ALL_STRUCTURES))
def test_update_matches_oracle(name, rng):
    s, oracle = _build(S.ALL_STRUCTURES[name], rng)
    keys = list(oracle)[:20]
    for i, key in enumerate(keys):
        assert s.update(key, i)
        assert s.get(key) == i, name
    assert not s.update(10**9, 1)


@given(st.lists(st.integers(min_value=0, max_value=10**6), min_size=1,
                max_size=300, unique=True),
       st.sampled_from(sorted(S.ALL_STRUCTURES)))
@settings(max_examples=40, deadline=None)
def test_structures_property(keys, name):
    keys = np.asarray(keys, np.int64)
    values = keys * 7 + 1
    s = S.ALL_STRUCTURES[name]()
    s.bulk_load(keys, values)
    probe = keys[len(keys) // 2]
    assert s.get(int(probe)) == int(probe) * 7 + 1
    lo, hi = int(keys.min()), int(keys.max()) + 1
    assert sorted(s.range_get(lo, hi)) == sorted(values.tolist())


def test_measure_workload_runs(rng):
    s = S.BPlusTree()
    keys = rng.permutation(5000).astype(np.int64)
    values = keys.copy()
    out = S.measure_workload(s, keys, values, queries=keys[:100])
    assert out["bulk_load_s"] > 0 and out["per_query_s"] > 0
