"""Fault-injection harness + self-healing serving paths (PR 8).

Every failure path the serving tier claims to survive is exercised here
deterministically on CPU CI via :mod:`repro.testing.faults`: shard retry
on a different device, device quarantine + half-open probe recovery, the
fused -> flat -> grouped degraded-engine chain (against the scalar
oracle), worker resurrection with typed ``WorkerCrashed`` futures, part
timeouts with abandoned-future accounting, and snapshot-restore outcome
counters.  No wall-clock randomness: every plan is seeded and rules fire
at explicit occurrences or with ``rate=1.0`` under ``max_fires`` caps.
"""
import time

import numpy as np
import pytest

from repro.core import devicecost, elements as el, whatif
from repro.core.batchcost import pack_frontier
from repro.core.hardware import hw1
from repro.core.synthesis import Workload, cost_workload
from repro.serving import (DesignCalculatorService, ScoringShardPool,
                           WorkerCrashed)
from repro.testing import faults
from repro.testing.faults import FaultInjected, FaultPlan, FaultRule

pytestmark = pytest.mark.chaos

W = Workload(n_entries=150_000, n_queries=100)
MIX = {"get": 60.0, "range_get": 20.0, "update": 20.0}


def _packed():
    return pack_frontier([el.spec_btree(), el.spec_hash_table(),
                          el.spec_skip_list(), el.spec_trie()], W, MIX)


def _service(hw, **kwargs):
    kwargs.setdefault("window_s", 0.002)
    return DesignCalculatorService([hw], **kwargs)


# ---------------------------------------------------------------------------
# The harness itself
# ---------------------------------------------------------------------------
def test_fault_plan_is_seed_deterministic():
    def pattern(seed):
        plan = FaultPlan(seed, [FaultRule("x", kind="error", rate=0.5)])
        hits = []
        with plan.activate():
            for i in range(200):
                try:
                    faults.check("x", key="k")
                    hits.append(0)
                except FaultInjected:
                    hits.append(1)
        return hits, plan.fires()

    first, fires = pattern(42)
    again, fires2 = pattern(42)
    assert first == again and fires == fires2
    assert 40 < fires < 160    # rate=0.5 actually fires, and not always


def test_seams_are_noops_without_a_plan():
    assert faults.active() is None
    faults.check("anything", key=7)     # must not raise
    value = np.ones(3)
    assert faults.corrupt("anything", value) is value


def test_only_one_plan_active_per_process():
    with FaultPlan(0, []).activate():
        with pytest.raises(RuntimeError, match="already active"):
            with FaultPlan(1, []).activate():
                pass
    faults.check("fine")    # the seams are clean again


def test_corrupt_poisons_float_leaves_only():
    plan = FaultPlan(0, [FaultRule("s", kind="corrupt", rate=1.0)])
    banks = {"f": np.arange(3, dtype=np.float64),
             "i": np.arange(3, dtype=np.int32)}
    with plan.activate():
        out = faults.corrupt("s", banks)
    assert np.isnan(out["f"]).all()             # float leaves poisoned
    assert np.array_equal(out["i"], banks["i"])  # gather indices intact


# ---------------------------------------------------------------------------
# Shard pool healing
# ---------------------------------------------------------------------------
@pytest.mark.devices(2)
def test_failed_part_retries_on_a_different_device(device_count):
    assert device_count >= 2
    pool = ScoringShardPool(2, part_timeout_s=5.0)
    hw = hw1()
    packed = _packed()
    dev0 = pool.devices[0].id
    baseline = packed.score(hw, engine="fused", shard=False)
    plan = FaultPlan(3, [FaultRule("shards.dispatch", kind="error",
                                   key=dev0, at=(0,))])
    try:
        with plan.activate():
            totals, _ = pool.score_frontier(packed, hw)
        assert np.allclose(totals, baseline, rtol=1e-6)
        assert pool.stats()["shard_retries"] == 1
        retries = [e for e in pool.recent_events() if e[0] == "retry"]
        assert retries and all(frm != to for _, _, frm, to in retries)
    finally:
        pool.close()


def test_quarantine_opens_and_half_open_probe_recovers():
    pool = ScoringShardPool(1, quarantine_after=2, quarantine_s=0.25,
                            part_timeout_s=5.0)
    hw = hw1()
    packed = _packed()
    baseline = packed.score(hw, engine="fused", shard=False)
    # exactly two dispatch failures: initial + same-device retry -> the
    # breaker opens; the flat rescore still answers the window
    plan = FaultPlan(5, [FaultRule("shards.dispatch", kind="error",
                                   rate=1.0, max_fires=2)])
    try:
        with plan.activate():
            totals, _ = pool.score_frontier(packed, hw)
            assert np.allclose(totals, baseline, rtol=1e-6)
            stats = pool.stats()
            assert stats["shard_rescored"] == 1
            assert stats["device_quarantines"] == 1
            health = pool.device_health()[0]
            assert health["state"] == "quarantined"
            assert health["consecutive_failures"] == 2
            time.sleep(0.3)
            assert pool.device_health()[0]["state"] == "half-open"
            # next pick is the probe; the rule is spent, so it succeeds
            totals, _ = pool.score_frontier(packed, hw)
        assert np.allclose(totals, baseline, rtol=1e-6)
        stats = pool.stats()
        assert stats["device_probes"] >= 1
        assert stats["device_recoveries"] == 1
        assert pool.device_health()[0]["state"] == "ok"
        kinds = [e[0] for e in pool.recent_events()]
        assert ["quarantine", "probe", "recover"] == \
            [k for k in kinds if k != "retry"]
    finally:
        pool.close()


def test_hung_part_times_out_and_is_abandoned():
    pool = ScoringShardPool(1, part_timeout_s=0.05)
    hw = hw1()
    packed = _packed()
    baseline = packed.score(hw, engine="fused", shard=False)
    # warm the device-routed jit through the executor path (a rule-free
    # plan forces it) so the timing below measures healing, not compiles
    with FaultPlan(0, []).activate():
        pool.score_frontier(packed, hw)
    plan = FaultPlan(9, [FaultRule("shards.dispatch", kind="hang",
                                   rate=1.0, hang_s=0.5, max_fires=1)])
    try:
        with plan.activate():
            t0 = time.monotonic()
            totals, _ = pool.score_frontier(packed, hw)
        assert time.monotonic() - t0 < 0.45   # did not wait out the hang
        assert np.allclose(totals, baseline, rtol=1e-6)
        stats = pool.stats()
        assert stats["shard_timeouts"] == 1
        assert stats["abandoned_parts"] == 1   # uncancellable, accounted
        assert stats["shard_retries"] == 1
    finally:
        pool.close()


def test_corrupt_fused_output_heals_inside_the_pool():
    hw = hw1()
    with _service(hw) as svc:
        q = (el.spec_btree(), el.spec_csb_tree(), W, hw)
        plan = FaultPlan(11, [FaultRule("devicecost.fused",
                                        kind="corrupt", at=(0,))])
        with plan.activate():
            got = svc.what_if_design(*q)
        assert plan.fires("devicecost.fused") == 1
        oracle = whatif.what_if_design(*q, engine="scalar")
        assert got.baseline_seconds == pytest.approx(
            oracle.baseline_seconds, rel=1e-6)
        assert got.variant_seconds == pytest.approx(
            oracle.variant_seconds, rel=1e-6)
        # healed below the engine chain: the retried dispatch was clean
        assert got.engine == "fused"
        stats = svc.stats()
        assert stats["shard_nonfinite"] >= 1
        assert stats["shard_retries"] >= 1
        assert stats["fallback_grouped"] == 0


# ---------------------------------------------------------------------------
# Degraded-engine fallback chain
# ---------------------------------------------------------------------------
def test_nan_banks_fall_back_to_oracle_then_probe_recovers():
    hw = hw1()
    with _service(hw, engine_probe_s=0.3) as svc:
        q = (el.spec_btree(), el.spec_csb_tree(), W, hw)
        oracle = whatif.what_if_design(*q, engine="scalar")
        # poison the NEXT bank build (the live table must be dropped for
        # the corruption to reach the scorer), then ask
        devicecost.invalidate_table(hw)
        plan = FaultPlan(13, [FaultRule("devicecost.banks",
                                        kind="corrupt", rate=1.0,
                                        max_fires=1)])
        with plan.activate():
            got = svc.what_if_design(*q)
        assert plan.fires("devicecost.banks") == 1
        # sharded fused and flat fused both saw NaN banks; the grouped
        # oracle answered, exactly
        assert got.engine == "grouped"
        assert got.baseline_seconds == pytest.approx(
            oracle.baseline_seconds, rel=1e-9)
        assert got.variant_seconds == pytest.approx(
            oracle.variant_seconds, rel=1e-9)
        stats = svc.stats()
        assert stats["nonfinite_groups"] >= 2
        assert stats["fallback_grouped"] == 1
        assert stats["engine_degraded"] == 1
        health = svc.health()["engines"][hw.name]
        assert health["degraded"] and health["engine"] == "grouped"
        # still inside the probe window: the oracle keeps serving
        got2 = svc.what_if_design(*q)
        assert got2.engine == "grouped"
        assert svc.stats()["fallback_grouped"] == 2
        time.sleep(0.35)
        # probe window open: the fused attempt rebuilds clean banks
        # (invalidate_table) and succeeds -> recovery
        got3 = svc.what_if_design(*q)
        assert got3.engine == "fused"
        assert got3.baseline_seconds == pytest.approx(
            oracle.baseline_seconds, rel=1e-6)
        stats = svc.stats()
        assert stats["engine_recovered"] == 1
        assert not svc.health()["engines"][hw.name]["degraded"]


# ---------------------------------------------------------------------------
# Worker supervision
# ---------------------------------------------------------------------------
def test_worker_crash_fails_inflight_typed_and_resurrects():
    hw = hw1()
    with _service(hw) as svc:
        q = (el.spec_btree(), el.spec_csb_tree(), W, hw)
        plan = FaultPlan(17, [FaultRule("service.worker", kind="error",
                                        at=(0,))])
        with plan.activate():
            fut = svc.submit_design(*q)
            with pytest.raises(WorkerCrashed) as err:
                fut.result(timeout=30)
        assert isinstance(err.value.cause, FaultInjected)
        assert err.value.restarts == 1
        # the resurrected worker serves the next request normally
        got = svc.what_if_design(*q)
        oracle = whatif.what_if_design(*q, engine="scalar")
        assert got.baseline_seconds == pytest.approx(
            oracle.baseline_seconds, rel=1e-6)
        assert svc.stats()["worker_restarts"] == 1
        assert svc.health()["worker_alive"]


# ---------------------------------------------------------------------------
# Snapshot-restore outcomes (satellite S3 regression)
# ---------------------------------------------------------------------------
def test_corrupt_snapshot_is_counted_and_cold_starts(tmp_path):
    path = tmp_path / "snap.pkl"
    path.write_bytes(b"this is not a pickle")
    hw = hw1()
    with _service(hw, snapshot_path=str(path)) as svc:
        stats = svc.stats()
        assert stats["snapshot_corrupt"] == 1
        assert stats["snapshot_discarded"] == 1
        assert stats["snapshot_entries"] == 0
        assert svc.health()["snapshot"]["outcome"] == "corrupt"
        # cold start is fine: the service still answers
        q = (el.spec_btree(), el.spec_csb_tree(), W, hw)
        assert svc.what_if_design(*q).baseline_seconds == pytest.approx(
            cost_workload(el.spec_btree(), W, hw), rel=1e-6)


def test_restored_snapshot_is_counted(tmp_path):
    path = tmp_path / "snap.pkl"
    hw = hw1()
    with _service(hw, snapshot_path=str(path)) as svc:
        svc.what_if_design(el.spec_btree(), el.spec_csb_tree(), W, hw)
        assert svc.save_snapshot() > 0
    with _service(hw, snapshot_path=str(path)) as svc:
        stats = svc.stats()
        assert stats["snapshot_restored"] == 1
        assert stats["snapshot_discarded"] == 0
        assert stats["snapshot_entries"] > 0
        assert svc.health()["snapshot"]["outcome"] == "restored"
