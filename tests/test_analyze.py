"""repro-lint framework tests: every checker must demonstrably fire on
its seeded fixture, stay silent on the clean twin, honor documented
suppressions, and report stably over the CLI."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.lint

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "lint")


def _run(checker_name, *relpaths):
    from tools.analyze import run_paths
    from tools.analyze.checkers import BY_NAME
    paths = [os.path.join(FIXTURES, *rp.split("/")) for rp in relpaths]
    return run_paths(paths, checkers=[BY_NAME[checker_name]],
                     baseline=None)


def _rules(findings):
    return {f.rule for f in findings}


# -- cache-keys -------------------------------------------------------------

def test_cache_keys_fires_on_seeded_fixture():
    findings = _run("cache-keys", "cache_keys/bad.py")
    assert _rules(findings) == {"hardware-in-key", "workload-in-key"}
    hw = [f for f in findings if f.rule == "hardware-in-key"]
    assert len(hw) == 2, "both the .get and the .put key must be flagged"
    assert all(f.path.endswith("cache_keys/bad.py") and f.line > 0
               for f in findings)


def test_cache_keys_silent_on_clean_twin():
    assert _run("cache-keys", "cache_keys/clean.py") == []


# -- locks ------------------------------------------------------------------

def test_locks_fires_on_seeded_fixture():
    findings = _run("locks", "locks/bad.py")
    assert _rules(findings) == {"unlocked"}
    msgs = [f.message for f in findings]
    assert any("_data" in m for m in msgs), "unlocked field read"
    assert any("_hits" in m for m in msgs), "unlocked field write"
    assert any("REGISTRY" in m for m in msgs), "unlocked guarded global"


def test_locks_silent_on_clean_twin_and_honors_suppression():
    # clean.py contains an unlocked read carrying a documented
    # '# lint: unlocked(...)' — the run must come back empty anyway
    assert _run("locks", "locks/clean.py") == []


# -- futures ----------------------------------------------------------------

def test_futures_fires_on_seeded_fixture():
    findings = _run("futures", "futures/bad.py")
    assert _rules(findings) == {"dropped-future", "unawaited-future",
                                "untimed-wait"}
    untimed = [f for f in findings if f.rule == "untimed-wait"]
    assert len(untimed) == 2, "helper-returned and chained waits"


def test_futures_silent_on_clean_twin_and_honors_suppression():
    assert _run("futures", "futures/clean.py") == []


# -- jit-safety -------------------------------------------------------------

def test_jit_safety_fires_on_seeded_fixture():
    findings = _run("jit-safety", "jit_safety/bad.py")
    assert _rules(findings) == {"traced-branch", "traced-concretize",
                                "array-closure", "unhashable-static"}
    concretize = [f for f in findings if f.rule == "traced-concretize"]
    assert any("_pad" in f.message for f in concretize), \
        "the helper reached through its call site must be flagged"


def test_jit_safety_silent_on_clean_twin():
    assert _run("jit-safety", "jit_safety/clean.py") == []


# -- docs-refs --------------------------------------------------------------

def test_docs_refs_fires_and_stays_silent():
    from tools.analyze.checkers import docs_refs
    bad = os.path.join(FIXTURES, "docs_refs", "bad.md")
    clean = os.path.join(FIXTURES, "docs_refs", "clean.md")
    errors = docs_refs.check_doc_texts([bad])
    assert len(errors) == 2
    assert any("not_a_real_function" in e for e in errors)
    assert any("nonexistent.py" in e for e in errors)
    assert docs_refs.check_doc_texts([clean]) == []


# -- framework --------------------------------------------------------------

def test_bare_suppression_is_itself_reported():
    findings = _run("locks", "framework/bare.py")
    assert [f.rule for f in findings] == ["bare-suppression"]
    assert findings[0].checker == "framework"


def test_json_report_is_stable():
    from tools.analyze import render_json
    findings = _run("futures", "futures/bad.py")
    report = json.loads(render_json(findings))
    assert report["version"] == 1
    assert report["count"] == len(findings) > 0
    for entry in report["findings"]:
        assert set(entry) == {"path", "line", "checker", "rule", "message"}


def test_baseline_subtracts_known_findings(tmp_path):
    from tools.analyze import run_paths
    from tools.analyze.checkers import BY_NAME
    bad = os.path.join(FIXTURES, "futures", "bad.py")
    findings = run_paths([bad], checkers=[BY_NAME["futures"]],
                         baseline=None)
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps([f.to_dict() for f in findings]))
    assert run_paths([bad], checkers=[BY_NAME["futures"]],
                     baseline=str(baseline)) == []


# -- CLI --------------------------------------------------------------------

def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *args],
        cwd=ROOT, capture_output=True, text=True, timeout=120)


def test_cli_exits_nonzero_with_json_on_findings():
    proc = _cli("tests/fixtures/lint/futures/bad.py",
                "--checker", "futures", "--baseline", "none",
                "--format", "json")
    assert proc.returncode == 1, proc.stderr
    report = json.loads(proc.stdout)
    assert report["count"] > 0


def test_cli_exits_zero_on_clean_input():
    proc = _cli("tests/fixtures/lint/futures/clean.py",
                "--checker", "futures", "--baseline", "none")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 findings" in proc.stdout


def test_cli_rejects_unknown_checker():
    proc = _cli("--checker", "no-such-checker")
    assert proc.returncode == 2
