"""Fused device-resident frontier scoring (PR 2 tentpole): parameter-table
swaps without recompilation, sharded scoring, bank coverage of every model
kind, and the bounded compiled-shape set."""
import numpy as np
import pytest

from repro.core import batchcost, devicecost, elements as el, models, whatif
from repro.core.batchcost import cost_many, pack_frontier
from repro.core.hardware import HardwareProfile, hw1, hw2, hw3
from repro.core.synthesis import Workload


def _frontier(n_entries=500_000):
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list(),
             el.spec_btree(fanout=40), el.spec_btree(fanout=10)]
    return specs, Workload(n_entries=n_entries), {"get": 10.0, "update": 5.0}


def test_whatif_hardware_swaps_table_without_recompilation(hw_analytical):
    """The acceptance probe: once a frontier shape is compiled, scoring it
    on *new* hardware is a pure parameter-table swap — the jit cache must
    serve every what-if-hardware question with zero retraces."""
    specs, w, mix = _frontier()
    packed = pack_frontier(specs, w, mix)
    packed.score(hw1())                      # may compile this shape once
    before = devicecost.trace_count()
    totals = {}
    for hw in (hw2(), hw3(), hw1()):
        totals[hw.name] = packed.score(hw)
    assert devicecost.trace_count() == before
    # the swap changes answers (different hardware), not shapes
    assert not np.allclose(totals["HW2"], totals["HW3"])
    # a one-design what-if frontier is its own (smaller) bucket shape: it
    # may compile once, after which hardware swaps stay recompile-free
    whatif.what_if_hardware(specs[0], w, hw1(), hw3(), mix)
    before = devicecost.trace_count()
    ans = whatif.what_if_hardware(specs[0], w, hw2(), hw3(), mix)
    assert devicecost.trace_count() == before
    assert ans.beneficial  # HW3 is strictly faster in every constant


def test_bucketing_bounds_compiled_shapes(hw_analytical):
    """Frontier sizes vary call to call; pow2 bucketing must keep the
    compiled-shape set bounded — many same-bucket frontiers, one trace."""
    specs, w, mix = _frontier()
    cost_many(specs[:3], w, hw_analytical, mix)
    before = devicecost.trace_count()
    for k in (2, 3, 4, 5, 4, 3, 2):          # all within the same buckets
        cost_many(specs[:k], w, hw_analytical, mix)
    assert devicecost.trace_count() == before


def test_sharded_path_matches_single_device(hw_analytical):
    specs, w, mix = _frontier()
    packed = pack_frontier(specs * 40, w, mix)   # 200 designs
    single = packed.score(hw_analytical, shard=False)
    sharded = packed.score(hw_analytical, shard=True)
    np.testing.assert_allclose(sharded, single, rtol=1e-12)


def test_chunked_scoring_matches_unchunked(hw_analytical, monkeypatch):
    specs, w, mix = _frontier()
    packed = pack_frontier(specs * 40, w, mix)
    full = packed.score(hw_analytical)
    monkeypatch.setattr(devicecost, "_MAX_FUSED_RECORDS", 256)
    chunked = packed.score(hw_analytical)
    np.testing.assert_allclose(chunked, full, rtol=1e-6)


def _knn_profile(base: HardwareProfile, n_points: int) -> HardwareProfile:
    """A profile whose quicksort model is a trained k-NN (Table 1 allows
    any family per primitive) — exercises the knn bank end to end."""
    xs = np.logspace(1, 6, n_points)
    ys = 2e-9 * xs * np.log(xs) + 1e-8
    models_ = dict(base.models)
    models_["quicksort"] = models.fit("knn", xs, ys)
    return HardwareProfile(base.name + "+knn", models_)


@pytest.mark.parametrize("n_points", [12, 3], ids=["knn", "knn-small"])
def test_knn_models_join_the_device_table(hw_analytical, n_points):
    """The jittable fixed-k top-k covers any support size: sentinel slots
    carry zero weight, so n < 4 reduces to the numpy k=min(4, n) result."""
    hw = _knn_profile(hw1(), n_points)
    specs, w, mix = _frontier()
    fused = cost_many(specs, w, hw, mix)
    grouped = cost_many(specs, w, hw, mix, engine="grouped")
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)
    table = devicecost.device_table(hw)
    assert table.has_knn


def test_sigmoids2d_banks_as_its_m1_slice(hw_analytical):
    x = np.tile(np.logspace(2, 6, 20), 4)
    m_in = np.repeat([1, 2, 3, 4], 20)
    y = (1e-8 / (1 + np.exp(-(np.log(x + 1.0) - 8.0)))) * m_in
    hw = hw1()
    hw = HardwareProfile("HW1+2d", dict(hw.models))
    hw.models["bloom_probe_multiply_shift"] = models.fit2d_sigmoids(
        x, m_in, y)
    specs = [whatif.add_bloom_filters(el.spec_btree())]
    w = Workload(n_entries=200_000)
    fused = cost_many(specs, w, hw, {"get": 5.0})
    grouped = cost_many(specs, w, hw, {"get": 5.0}, engine="grouped")
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)


def test_foreign_interned_model_does_not_poison_pads():
    """Regression: pad rows used to carry model id 0; once some *other*
    profile's model name claimed that global id, weight-0 pads tripped the
    availability check on profiles that never fit it.  Needs a fresh
    process so the foreign name is interned first (id 0)."""
    import os
    import subprocess
    import sys
    code = (
        "import numpy as np\n"
        "from repro.core import batchcost, devicecost, elements as el\n"
        "from repro.core.hardware import hw1\n"
        "from repro.core.synthesis import Workload\n"
        "devicecost.model_id('exotic_model')   # claims global id 0\n"
        "w = Workload(n_entries=10_000)\n"
        "fused = batchcost.cost_many([el.spec_btree()], w, hw1())\n"
        "grouped = batchcost.cost_many([el.spec_btree()], w, hw1(),\n"
        "                              engine='grouped')\n"
        "np.testing.assert_allclose(fused, grouped, rtol=1e-6)\n"
        "print('PADS-OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "PADS-OK" in proc.stdout


def test_missing_model_raises_keyerror(hw_analytical):
    specs, w, mix = _frontier()
    partial = HardwareProfile("partial", {
        k: m for k, m in hw1().models.items() if "write" not in k})
    with pytest.raises(KeyError, match="write"):
        cost_many(specs, w, partial, mix)


def test_replace_derived_profile_rebuilds_banks(hw_analytical):
    """Regression: a profile derived via dataclasses.replace must never
    score frontiers with its parent's cached parameter banks."""
    import dataclasses
    specs, w, mix = _frontier()
    hw = hw1()
    cost_many(specs, w, hw, mix)            # builds + caches hw's table
    derived = dataclasses.replace(hw, name="HW1-as-HW3",
                                  models=hw3().models)
    fused = cost_many(specs, w, derived, mix)
    grouped = cost_many(specs, w, derived, mix, engine="grouped")
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)
    assert not np.allclose(fused, cost_many(specs, w, hw, mix))


def test_device_table_cached_per_profile(hw_analytical):
    hw = hw1()
    t1 = devicecost.device_table(hw)
    assert devicecost.device_table(hw) is t1
    # a different profile builds its own banks but shares bank shapes
    # (that shape-sharing is what makes the swap recompile-free)
    t2 = devicecost.device_table(hw3())
    assert t2 is not t1
    assert {k: v.shape for k, v in t1.banks.items()} == \
        {k: v.shape for k, v in t2.banks.items()}


def test_tile_padding_is_invisible(hw_analytical):
    """Pad rows (weight 0, model row 0) must contribute exactly nothing:
    a one-design frontier equals its cost_workload_batched total."""
    from repro.core.batchcost import cost_workload_batched
    spec = el.spec_btree()
    w = Workload(n_entries=100_000)
    packed = pack_frontier([spec], w, None)
    assert len(packed.ids) % devicecost.TILE == 0
    assert cost_workload_batched(spec, w, hw_analytical, engine="grouped") \
        == pytest.approx(float(packed.score(hw_analytical)[0]), rel=1e-6)
