"""Sharding-rule unit tests (single device: rules evaluated against
AbstractMesh shapes; real-device SPMD runs live in test_distributed.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.parallel import ctx
from repro.parallel.sharding import FSDP_MIN_ELEMS, spec_for_param


def _abstract_mesh(sizes, names):
    try:  # jax >= 0.4.35: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:  # older jax: AbstractMesh(sizes, names)
        return AbstractMesh(sizes, names)


def mesh_single():
    return _abstract_mesh((16, 16), ("data", "model"))


def mesh_multi():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


@pytest.mark.parametrize("mesh_fn", [mesh_single, mesh_multi])
def test_attention_projection_sharding(mesh_fn):
    mesh = mesh_fn()
    # llama3-405b wq: [layers, d, heads, hd] = [126, 16384, 128, 128]
    spec = spec_for_param("layers/attn/wq", (126, 16384, 128, 128), mesh)
    assert spec[-2] == "model"          # heads TP-sharded
    assert spec[-3] == "data"           # FSDP on d
    # GQA kv with 8 heads (not divisible by 16): falls back to head_dim
    spec = spec_for_param("layers/attn/wk", (126, 16384, 8, 128), mesh)
    assert spec[-1] == "model" and spec[-2] is None


def test_fsdp_toggle():
    mesh = mesh_single()
    with_fsdp = spec_for_param("layers/mlp/w_gate", (28, 1536, 8960), mesh,
                               fsdp=True)
    without = spec_for_param("layers/mlp/w_gate", (28, 1536, 8960), mesh,
                             fsdp=False)
    assert "data" in tuple(with_fsdp)
    assert "data" not in tuple(without)
    assert "model" in tuple(without)    # TP stays


def test_small_params_stay_replicated():
    mesh = mesh_single()
    spec = spec_for_param("final_norm/scale", (1024,), mesh)
    assert tuple(spec) in ((), (None,))


def test_moe_expert_parallelism():
    mesh = mesh_single()
    # phi3.5: [32 layers, 16 experts, 4096, 6400]
    spec = spec_for_param("layers/moe/w_gate", (32, 16, 4096, 6400), mesh)
    assert spec[-3] == "model"          # EP on the expert dim
    assert spec[-2] == "data"


def test_vocab_sharding():
    mesh = mesh_single()
    spec = spec_for_param("embed/tok", (128256, 16384), mesh)
    assert spec[-2] == "model"
    spec = spec_for_param("embed/head", (16384, 128256), mesh)
    assert spec[-1] == "model"


def test_indivisible_dims_left_unsharded():
    mesh = mesh_single()
    # vocab 32064 not divisible by 16? 32064/16=2004 — divisible; use odd
    spec = spec_for_param("embed/tok", (32063, 1536), mesh)
    assert spec[0] is None


# ---------------------------------------------------------------------------
# activation-sharding context (no mesh installed -> no-ops)
# ---------------------------------------------------------------------------
def test_ctx_noop_without_mesh():
    x = jnp.ones((4, 8, 16))
    assert ctx.constrain_bsd(x) is x
    assert ctx.constrain_residual(x) is x
    assert ctx.get_mesh() is None


def test_ctx_options_restore():
    assert not ctx.sequence_parallel()
    with ctx.options(seq_parallel=True):
        assert ctx.sequence_parallel()
    assert not ctx.sequence_parallel()


def test_ctx_batch_axes_follow_mesh_names():
    assert ctx.batch_axes() == ()
