"""Element library (Figure 30) and data structure specifications."""
import pytest

from repro.core import elements as el
from repro.core.elements import DataStructureSpec, Element


def test_element_library_matches_figure30():
    udp = el.unordered_data_page()
    assert udp.terminal and udp.retains_keys and udp.retains_values
    assert udp.tag("key_partitioning") == "append"

    odp = el.ordered_data_page()
    assert odp.sorted_keys and odp.tag("area_links") == "forward"
    assert odp.get("utilization") == (">=", 0.5)

    hsh = el.hash_element()
    assert not hsh.retains_keys and not hsh.retains_values
    assert hsh.get("key_partitioning")[1] == "func"
    assert hsh.get("sub_block_capacity") == "unrestricted"

    bt = el.btree_internal()
    assert bt.fanout == 20 and bt.tag("zone_map_filters") == "min"
    assert bt.get("sub_block_capacity") == "balanced"
    assert bt.get("recursion") == ("yes", "logn")

    csb = el.csb_internal()
    assert csb.tag("sub_block_physical_layout") == "BFS"

    fast = el.fast_internal()
    assert fast.tag("sub_block_physical_location") == "inline"
    assert fast.tag("sub_block_physical_layout") == "BFS-layer"

    ll = el.linked_list_element()
    assert ll.tag("immediate_node_links") == "next"
    assert ll.tag("intra_node_access") == "head_link"

    sl = el.skip_list_element()
    assert sl.tag("skip_node_links") == "perfect"
    assert sl.tag("zone_map_filters") == "both"

    trie = el.trie_element()
    assert trie.tag("key_retention") == "func"
    assert trie.get("recursion")[0] == "yes"


def test_invalid_element_raises():
    with pytest.raises(ValueError):
        Element.make("bad", key_retention="maybe")
    with pytest.raises(ValueError):
        Element.make("bad", fanout=("terminal", 16),
                     sub_block_physical_layout="BFS")


def test_spec_requires_terminal_last():
    with pytest.raises(ValueError):
        DataStructureSpec("x", (el.btree_internal(),))
    with pytest.raises(ValueError):
        DataStructureSpec("x", (el.unordered_data_page(),
                                el.unordered_data_page()))


def test_all_paper_specs_construct():
    import inspect
    for name, make in el.ALL_PAPER_SPECS.items():
        sig = inspect.signature(make)
        spec = make(1000) if "n_puts" in sig.parameters else make()
        assert spec.terminal.terminal
        assert "->" in spec.describe() or len(spec.chain) == 1


def test_with_values_override():
    leaf = el.ordered_data_page().with_values(
        bloom_filters=("on", 4, 1 << 14),
        filters_memory_layout="scatter")
    assert leaf.tag("bloom_filters") == "on"
    # original untouched (immutability)
    assert el.ordered_data_page().tag("bloom_filters") == "off"
