"""Layout primitives, invalidation rules, and design-space cardinality
(paper §2 / Appendix C, Equations 1-4)."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sweeps
    from repro.testing.hypothesis_fallback import (
        given, settings, strategies as st)

from repro.core import design_space
from repro.core.primitives import (INVALIDATION_RULES, PRIMITIVES,
                                   enumerate_elements, tag_of,
                                   validate_assignment)


def test_all_21_primitives_present():
    assert len(PRIMITIVES) == 21


def test_domains_match_tags():
    for prim in PRIMITIVES.values():
        for value in prim.domain:
            assert prim.validate(value), (prim.name, value)


def test_unknown_primitive_rejected():
    assert validate_assignment({"no_such_primitive": "yes"})


def test_out_of_domain_value_rejected():
    errors = validate_assignment({"key_retention": "maybe"})
    assert any("outside domain" in e for e in errors)


def test_rule_kv_layout_requires_retention():
    errors = validate_assignment({
        "key_retention": "no", "value_retention": "no",
        "key_value_layout": "columnar"})
    assert any("retention" in e for e in errors)


def test_rule_terminal_excludes_child_primitives():
    errors = validate_assignment({
        "fanout": ("terminal", 256),
        "sub_block_physical_layout": "BFS"})
    assert any("requires fanout != terminal" in e for e in errors)


def test_rule_links_location():
    errors = validate_assignment({
        "immediate_node_links": "none", "skip_node_links": "none",
        "links_location": "scatter"})
    assert any("links" in e for e in errors)


def test_enumerate_elements_yields_valid_assignments():
    names = ("key_retention", "value_retention", "key_value_layout",
             "fanout")
    count = 0
    for values in enumerate_elements(names, max_count=64):
        assert not validate_assignment(values)
        count += 1
    assert count > 0


# -- hypothesis: any combination drawn from the primitive domains either
# validates cleanly or every reported error names a real rule -------------
@st.composite
def assignments(draw):
    names = draw(st.lists(st.sampled_from(sorted(PRIMITIVES)), min_size=1,
                          max_size=8, unique=True))
    return {n: draw(st.sampled_from(PRIMITIVES[n].domain)) for n in names}


@given(assignments())
@settings(max_examples=200, deadline=None)
def test_validation_is_total_and_stable(values):
    errors = validate_assignment(values)
    assert errors == validate_assignment(values)  # deterministic
    for error in errors:
        assert isinstance(error, str) and error


# -- design-space cardinality (paper §2) ----------------------------------
def test_element_cardinality_matches_paper_order():
    log10 = math.log10(design_space.element_cardinality())
    assert 15.0 <= log10 <= 18.0          # paper: ~10^16


def test_two_element_structures_match_paper_order():
    log10 = math.log10(design_space.standard_design_cardinality(2))
    assert 30.0 <= log10 <= 36.0          # paper: ~10^32


def test_three_element_structures_match_paper_order():
    log10 = math.log10(design_space.standard_design_cardinality(3))
    assert 45.0 <= log10 <= 54.0          # paper: ~10^48


def test_polymorphic_exceeds_1e100_for_1e15_keys():
    assert design_space.polymorphic_design_cardinality(1e15) > 100.0


def test_fixed_library_comparison():
    # Appendix B: a 5-structure library yields 25 two-element designs
    assert design_space.fixed_library_cardinality(5, 2) == 25
