import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, for the tools.analyze lint framework (tools/ is a package)
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _memo_pollution_guard(request):
    """Bound the global memo state around every property-based test.

    Long property sweeps share one process-wide memo layer (segment /
    frontier / sweep caches plus every externally registered cache); a
    cache that grows past its declared bound — or that
    ``clear_caches()`` cannot drain — is cross-example pollution that
    can mask a parity failure behind a stale cached cost.  For tests
    carrying the ``properties`` marker this fixture starts them from a
    cold memo, snapshots ``cache_info()`` after the sweep, fails on any
    cache exceeding its bound, then proves the whole layer drains back
    to zero.  Non-property tests are untouched (several intentionally
    assert on warm-cache hit counters).
    """
    if request.node.get_closest_marker("properties") is None:
        yield
        return
    from repro.core import batchcost
    batchcost.clear_caches()
    yield
    grown = {name: info for name, info in batchcost.cache_info().items()
             if info.maxsize is not None and info.currsize > info.maxsize}
    assert not grown, (
        f"memo caches grew past their declared bounds during a property "
        f"sweep (cross-example pollution): {grown}")
    batchcost.clear_caches()
    undrained = {name: info.currsize
                 for name, info in batchcost.cache_info().items()
                 if info.currsize}
    assert not undrained, (
        f"clear_caches() left warm entries behind — an unregistered or "
        f"mis-registered memo: {undrained}")


@pytest.fixture(scope="session")
def cpu_profile():
    """A quickly-trained container hardware profile shared across tests."""
    from repro.core.training import quick_profile
    return quick_profile()


@pytest.fixture(scope="session")
def hw_analytical():
    from repro.core.hardware import hw1
    return hw1()


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)


@pytest.fixture()
def device_count(request):
    """The live ``jax.device_count()`` — with subprocess re-invocation.

    A test marked ``@pytest.mark.devices(n)`` that requests this fixture
    runs inline when the current process already has ``n`` devices;
    otherwise the fixture re-invokes the exact test node in a subprocess
    under ``--xla_force_host_platform_device_count=n`` (JAX pins its
    device list at backend init, so the count cannot change in-process —
    see :mod:`repro.testing.devices`), fails with the child's output on
    a child failure, and skips with a "verified in a subprocess" note on
    success.  One CI invocation thereby covers 2/8/48-way sharding.
    """
    import jax
    marker = request.node.get_closest_marker("devices")
    current = jax.device_count()
    if marker is None or current == int(marker.args[0]):
        return current
    wanted = int(marker.args[0])
    from repro.testing.devices import run_pytest_under_devices
    proc = run_pytest_under_devices(wanted, request.node.nodeid)
    if proc.returncode != 0:
        pytest.fail(
            f"failed under {wanted} forced host devices:\n"
            f"{proc.stdout[-4000:]}\n{proc.stderr[-2000:]}",
            pytrace=False)
    pytest.skip(f"verified in a subprocess under {wanted} forced host "
                f"devices")
