import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def cpu_profile():
    """A quickly-trained container hardware profile shared across tests."""
    from repro.core.training import quick_profile
    return quick_profile()


@pytest.fixture(scope="session")
def hw_analytical():
    from repro.core.hardware import hw1
    return hw1()


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)
