"""Workload-generalized frontier packing + the batched workload-sweep
engine (PR 5).

Every (design, workload) cell of a sweep must match the scalar oracle;
the grouped-engine grid must match the per-workload ``cost_many`` loop
bit for bit; repeat sweeps must be pure cache hits with zero fused-kernel
recompiles; degenerate and non-rectangular sweeps must degrade
gracefully; and the serving engine must coalesce sweep requests like the
PR-4 question kinds.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import batchcost, devicecost, elements as el, whatif
from repro.core.autocomplete import (complete_design, design_continuum,
                                     default_candidates, default_terminals,
                                     enumerate_completions)
from repro.core.batchcost import (concat_sweeps, cost_many, cost_sweep,
                                  normalize_points, pack_sweep)
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload, cost_workload
from repro.serving import DesignCalculatorService

BASE = Workload(n_entries=150_000, n_queries=100)


def _axis():
    """A realistic sweep axis: read fraction, skew, selectivity and query
    count all vary; the data size stays fixed (the rectangular case)."""
    workloads = [
        BASE,
        dataclasses.replace(BASE, zipf_alpha=0.8),
        dataclasses.replace(BASE, zipf_alpha=1.6, n_queries=1000),
        dataclasses.replace(BASE, selectivity=0.01),
        dataclasses.replace(BASE, zipf_alpha=0.4, selectivity=0.005),
    ]
    mixes = [
        {"get": 100.0},
        {"get": 80.0, "update": 20.0},
        {"get": 50.0, "update": 50.0},
        {"get": 60.0, "range_get": 30.0, "update": 10.0},
        {"get": 20.0, "range_get": 10.0, "update": 60.0,
         "bulk_load": 1.0},
    ]
    return workloads, mixes


def _frontier(depth: int = 2):
    return list(enumerate_completions((), default_candidates(),
                                      default_terminals(), depth, "sweep"))


def test_every_sweep_cell_matches_scalar_oracle(hw_analytical):
    """The acceptance contract: all (design, workload) cells of a fused
    sweep at 1e-6 of the per-cell scalar expert system."""
    workloads, mixes = _axis()
    specs = _frontier()
    grid = cost_sweep(specs, workloads, hw_analytical, mixes)
    assert grid.shape == (len(workloads), len(specs))
    scalar = np.asarray(
        [[cost_workload(s, w, hw_analytical, m) for s in specs]
         for w, m in zip(workloads, mixes)])
    np.testing.assert_allclose(grid, scalar, rtol=1e-6)
    # argmin per point — the continuum — agrees with the oracle
    assert np.array_equal(np.argmin(grid, axis=1),
                          np.argmin(scalar, axis=1))


def test_sweep_matches_per_workload_cost_many_exactly(hw_analytical):
    """The grouped-engine grid is BIT-identical to looping ``cost_many``
    per workload (same segments, same float64 accumulation order); the
    fused grid matches the fused loop to the engines' shared f32
    tolerance."""
    workloads, mixes = _axis()
    specs = _frontier()
    grid_g = cost_sweep(specs, workloads, hw_analytical, mixes,
                        engine="grouped")
    loop_g = np.stack([cost_many(specs, w, hw_analytical, m,
                                 engine="grouped")
                       for w, m in zip(workloads, mixes)])
    np.testing.assert_array_equal(grid_g, loop_g)
    grid_f = cost_sweep(specs, workloads, hw_analytical, mixes)
    loop_f = np.stack([cost_many(specs, w, hw_analytical, m)
                       for w, m in zip(workloads, mixes)])
    np.testing.assert_allclose(grid_f, loop_f, rtol=1e-6)


def test_degenerate_sweeps(hw_analytical):
    """1-workload and 0-design sweeps work end to end; 0 workloads and
    mismatched mixes are explicit errors."""
    w = Workload(n_entries=50_000)
    specs = [el.spec_btree(), el.spec_trie()]
    one = cost_sweep(specs, [w], hw_analytical)
    assert one.shape == (1, 2)
    np.testing.assert_allclose(one[0], cost_many(specs, w, hw_analytical),
                               rtol=0)
    empty = pack_sweep([], [w, dataclasses.replace(w, zipf_alpha=1.0)])
    assert empty.n_designs == 0
    for engine in ("fused", "grouped"):
        assert empty.score(hw_analytical, engine=engine).shape == (2, 0)
    with pytest.raises(ValueError, match="at least one workload"):
        pack_sweep(specs, [])
    with pytest.raises(ValueError, match="mixes"):
        pack_sweep(specs, [w], [{"get": 1.0}, {"get": 2.0}])
    with pytest.raises(ValueError, match="unknown engine"):
        pack_sweep(specs, [w]).score(hw_analytical, engine="bogus")


def test_repeat_sweeps_zero_recompiles_and_pure_cache_hits(hw_analytical):
    """Steady-state contract: a repeated sweep is one sweep-cache hit and
    one fused dispatch — no re-packing, no statics recompute, and zero
    XLA retraces, including across a what-if-hardware profile swap."""
    workloads, mixes = _axis()
    specs = _frontier()
    first = pack_sweep(specs, workloads, mixes)
    cost_sweep(specs, workloads, hw_analytical, mixes)   # warm the shape
    variant = hw3()
    cost_sweep(specs, workloads, variant, mixes)
    traces = devicecost.trace_count()
    info_before = batchcost.cache_info()
    for _ in range(3):
        cost_sweep(specs, workloads, hw_analytical, mixes)
    cost_sweep(specs, workloads, variant, mixes)         # pure table swap
    assert devicecost.trace_count() == traces
    assert pack_sweep(specs, workloads, mixes) is first
    info = batchcost.cache_info()
    # repeats are served whole from the sweep memo: no new misses in any
    # packing layer beneath it
    assert {k: v.misses for k, v in info.items()} == \
        {k: v.misses for k, v in info_before.items()}


def test_sweep_statics_shared_across_workloads(hw_analytical):
    """The PR-5 cache-key refactor, observable: packing one chain set
    under many same-structure workloads resolves template statics ONCE,
    and every point's segment references the *same* interned model-id
    array (only the numeric sizes/weights columns are per-workload)."""
    batchcost.clear_caches()
    workloads, _ = _axis()
    # one op set across all points (the read/write-ratio axis), so every
    # point shares one (template, ops) interning entry per chain
    mixes = whatif.read_fraction_mixes((1.0, 0.8, 0.6, 0.4, 0.2))
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list()]
    sweep = pack_sweep(specs, workloads, mixes)
    info = batchcost.cache_info()
    # one statics entry per distinct chain — NOT per (chain, workload)
    assert info["chain_statics"].currsize == len(specs)
    points = normalize_points(workloads, mixes)
    for ci, spec in enumerate(specs):
        segs = [batchcost._segment_cache.get(
            (spec.chain, w, mix_items)) for w, mix_items in points]
        assert all(s is not None for s in segs)
        ids0 = segs[0][0]
        for seg in segs[1:]:
            assert seg[0] is ids0, "per-workload segments must share " \
                "one interned ids array"
    # a later single-point pack_frontier reuses the sweep's segments
    before = batchcost.cache_info()["packed_spec"].misses
    packed = batchcost.pack_frontier(specs, workloads[1], mixes[1])
    assert batchcost.cache_info()["packed_spec"].misses == before
    np.testing.assert_allclose(packed.score(hw_analytical),
                               sweep.score(hw_analytical)[1], rtol=1e-6)


def test_sweep_repacks_only_missing_points(hw_analytical):
    """Sweeps and single-point calls feed each other: a sweep over a
    point already warmed by ``cost_many`` re-packs ONLY the cells it is
    actually missing (one new segment per chain per new point)."""
    batchcost.clear_caches()
    w1 = BASE
    w2 = dataclasses.replace(BASE, zipf_alpha=0.9)
    specs = [el.spec_btree(), el.spec_trie()]
    row1 = cost_many(specs, w1, hw_analytical)   # warms (chain, w1) cells
    before = batchcost.cache_info()["packed_spec"].misses
    grid = cost_sweep(specs, [w1, w2], hw_analytical)
    after = batchcost.cache_info()["packed_spec"].misses
    # exactly the (chain, w2) cells were missing — w1 cells were hits
    assert after == before + len(specs)
    np.testing.assert_allclose(grid[0], row1, rtol=1e-6)


def test_sweep_pad_rows_reference_real_model_ids(hw_analytical):
    """Bucket padding must repeat a real model id, never a blind 0: the
    scorer's availability check runs on the padded array, and a profile
    without a fitted model for the first-interned name must not reject
    sweeps that never use it."""
    sweep = pack_sweep([el.spec_btree()] * 5, [BASE])   # 80 -> bucket 128
    host_ids, _ = sweep._sweep_arrays()
    n = len(sweep.frontiers[0].ids)
    assert len(host_ids) > n, "pick a frontier that actually pads"
    assert (host_ids[n:] == host_ids[n - 1]).all()
    assert set(np.unique(host_ids)) <= set(np.unique(host_ids[:n]))


def test_mix_only_sweep_shares_sizes(hw_analytical):
    """A pure read/write-ratio sweep (one workload, varying mixes) shares
    every size column — only the mix weights differ across points."""
    mixes = whatif.read_fraction_mixes((1.0, 0.75, 0.5, 0.25, 0.0))
    sweep = pack_sweep([el.spec_btree(), el.spec_trie()],
                       [BASE] * len(mixes), mixes)
    assert sweep.rectangular
    f0 = sweep.frontiers[0]
    for f in sweep.frontiers[1:]:
        np.testing.assert_array_equal(f.sizes, f0.sizes)
    assert not np.array_equal(sweep.frontiers[0].weights,
                              sweep.frontiers[-1].weights)


def test_non_rectangular_sweep_degrades_gracefully(hw_analytical):
    """Data-size axes that change a chain's expansion depths cannot share
    a record layout; the sweep falls back to per-point frontiers spliced
    into one flat fused call — same grid contract, same oracle parity."""
    workloads = [Workload(n_entries=10_000),
                 Workload(n_entries=4_000_000)]
    specs = [el.spec_btree(), el.spec_hash_table()]
    sweep = pack_sweep(specs, workloads)
    assert not sweep.rectangular
    grid = sweep.score(hw_analytical)
    scalar = np.asarray(
        [[cost_workload(s, w, hw_analytical) for s in specs]
         for w in workloads])
    np.testing.assert_allclose(grid, scalar, rtol=1e-6)


def test_workload_sweep_answer_and_continuum(hw_analytical):
    """whatif.workload_sweep: grid + best-per-point accessors match the
    scalar-engine answer; design_continuum matches per-point
    complete_design exactly (same frontier, same argmin)."""
    workloads = [BASE, dataclasses.replace(BASE, zipf_alpha=1.2)]
    mixes = whatif.read_fraction_mixes((0.9, 0.3))
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_trie()]
    ans = whatif.workload_sweep(specs, workloads, hw_analytical, mixes)
    oracle = whatif.workload_sweep(specs, workloads, hw_analytical, mixes,
                                   engine="scalar")
    np.testing.assert_allclose(ans.totals, oracle.totals, rtol=1e-6)
    assert np.array_equal(ans.best_indices, oracle.best_indices)
    for i, (point, spec, cost) in enumerate(ans.continuum()):
        assert point == ans.points[i]
        assert spec is specs[int(ans.best_indices[i])]
        assert cost == float(ans.totals[i].min())
    assert "2 workloads x 3 designs" in ans.summary()

    results = design_continuum((), workloads, hw_analytical, mixes=mixes,
                               max_depth=2)
    for w, m, r in zip(workloads, mixes, results):
        single = complete_design((), w, hw_analytical, mix=m, max_depth=2)
        assert r.cost_seconds == pytest.approx(single.cost_seconds,
                                               rel=1e-9)
        assert r.spec.describe() == single.spec.describe()
        assert r.explored == single.explored


def test_serving_sweep_matches_direct_and_coalesces():
    """The service's sweep kind: answers match the direct engine, sweeps
    over the same point axis submitted in one window coalesce into one
    fused call, and session repeats hit the pinned sweep."""
    h1, h3 = hw1(), hw3()
    workloads = [BASE, dataclasses.replace(BASE, zipf_alpha=1.0)]
    mixes = whatif.read_fraction_mixes((1.0, 0.5))
    a = [el.spec_btree(), el.spec_trie()]
    b = [el.spec_skip_list()]
    direct_a = whatif.workload_sweep(a, workloads, h1, mixes)
    direct_b = whatif.workload_sweep(b, workloads, h1, mixes)
    with DesignCalculatorService([h1, h3], window_s=0.5) as svc:
        fut_a = svc.submit_sweep(a, workloads, h1, mixes)
        fut_b = svc.submit_sweep(b, workloads, h1, mixes)
        got_a, got_b = fut_a.result(), fut_b.result()
        stats = svc.stats()
        assert stats["sweeps"] == 2 and stats["failed"] == 0
        # both sweeps share the point axis -> one spliced fused call
        assert stats["score_calls"] == 1 and stats["coalesced"] == 2
        sess = svc.session("sweeper")
        sess.workload_sweep(a, workloads, h1, mixes)
        sess.workload_sweep(a, workloads, h1, mixes)
        assert svc.stats()["session_frontier_hits"] == 1
    np.testing.assert_allclose(got_a.totals, direct_a.totals, rtol=1e-9)
    np.testing.assert_allclose(got_b.totals, direct_b.totals, rtol=1e-9)
    assert got_a.question == direct_a.question


def test_serving_sweep_failure_isolation():
    """A sweep against an unregistered profile name fails its own future
    without poisoning the window's other requests."""
    h1 = hw1()
    workloads = [BASE]
    with DesignCalculatorService([h1]) as svc:
        ok = svc.submit_sweep([el.spec_btree()], workloads, h1)
        with pytest.raises(KeyError, match="unregistered"):
            svc.submit_sweep([el.spec_btree()], workloads, "nope")
        assert ok.result().totals.shape == (1, 1)


def test_concat_sweeps_contract(hw_analytical):
    """Splicing sweeps along the design axis scores identically to
    packing the concatenated spec list; mismatched point axes are
    rejected."""
    workloads = [BASE, dataclasses.replace(BASE, zipf_alpha=0.7)]
    a = [el.spec_btree(), el.spec_hash_table()]
    b = [el.spec_trie()]
    spliced = concat_sweeps([pack_sweep(a, workloads),
                             pack_sweep(b, workloads)])
    scratch = pack_sweep(a + b, workloads)
    np.testing.assert_array_equal(spliced.score(hw_analytical),
                                  scratch.score(hw_analytical))
    with pytest.raises(ValueError, match="different workload points"):
        concat_sweeps([pack_sweep(a, workloads),
                       pack_sweep(b, [BASE])])
    with pytest.raises(ValueError, match="at least one sweep"):
        concat_sweeps([])
