"""Crash-safety of the cross-PR benchmark trajectory files."""
import json
import os

import pytest

pytest.importorskip("benchmarks.common",
                    reason="benchmarks package needs repo root on sys.path")

from benchmarks import common


@pytest.fixture()
def bench_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "BENCH_DIR", str(tmp_path))
    return tmp_path


def _read(path):
    with open(path) as fh:
        return json.load(fh)


def test_emit_trajectory_appends_and_migrates(bench_dir, capsys):
    path = bench_dir / "BENCH_x.json"
    common.emit_trajectory("BENCH_x", "first", [{"a": 1}])
    common.emit_trajectory("BENCH_x", "second", [{"a": 2}])
    history = _read(path)
    assert [e["entry"] for e in history] == [0, 1]
    assert history[1]["label"] == "second"
    # legacy bare-rows files migrate into entry 0
    legacy = bench_dir / "BENCH_y.json"
    legacy.write_text(json.dumps([{"old": True}]))
    common.emit_trajectory("BENCH_y", "new", [{"a": 3}])
    history = _read(legacy)
    assert history[0]["label"] == "pre-trajectory"
    assert history[1]["label"] == "new"


def test_emit_trajectory_survives_corrupted_history(bench_dir, capsys):
    """A file truncated by a crash mid-dump must not poison every future
    append: the bad file is backed up and a fresh history starts."""
    path = bench_dir / "BENCH_x.json"
    path.write_text('[{"entry": 0, "label": "tru')     # torn json.dump
    common.emit_trajectory("BENCH_x", "after-crash", [{"a": 1}])
    history = _read(path)
    assert len(history) == 1 and history[0]["entry"] == 0
    assert history[0]["label"] == "after-crash"
    backups = [f for f in os.listdir(bench_dir) if ".corrupt-" in f]
    assert len(backups) == 1
    assert "tru" in (bench_dir / backups[0]).read_text()
    assert "corrupted" in capsys.readouterr().out
    # valid JSON of the wrong shape is quarantined the same way
    wrong = bench_dir / "BENCH_z.json"
    for payload in ("null", '{"rows": []}'):
        wrong.write_text(payload)
        common.emit_trajectory("BENCH_z", "recovered", [{"a": 1}])
        assert _read(wrong)[-1]["label"] == "recovered"


def test_emit_trajectory_write_is_atomic(bench_dir, monkeypatch):
    """The rewrite goes through a temp file + os.replace — a crash inside
    json.dump leaves the previous history intact (and no temp litter)."""
    path = bench_dir / "BENCH_x.json"
    common.emit_trajectory("BENCH_x", "first", [{"a": 1}])
    before = path.read_text()

    def boom(*args, **kwargs):
        raise KeyboardInterrupt("crash mid-dump")
    monkeypatch.setattr(common.json, "dump", boom)
    with pytest.raises(KeyboardInterrupt):
        common.emit_trajectory("BENCH_x", "doomed", [{"a": 2}])
    assert path.read_text() == before
    assert [f for f in os.listdir(bench_dir) if f != "BENCH_x.json"] == []
