"""Docs cannot rot silently: every module/function/path reference in
README.md and docs/*.md must resolve (tools/check_docs.py)."""
import importlib.util
import os

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    checker = _load_checker()
    files = checker.doc_files()
    assert any(f.endswith("README.md") for f in files)
    assert any(os.sep + "docs" + os.sep in f for f in files), \
        "docs/ has no markdown files"
    for f in files:
        assert os.path.exists(f), f


def test_docs_references_resolve():
    checker = _load_checker()
    assert checker.check_docs() == []


def test_checker_catches_stale_references(tmp_path, monkeypatch):
    """The checker itself must actually detect rot — a bogus module ref
    and a missing path in a scanned file must both be reported."""
    checker = _load_checker()
    bad = tmp_path / "README.md"
    bad.write_text("see repro.core.batchcost.not_a_real_function and "
                   "src/repro/core/nonexistent.py\n")
    monkeypatch.setattr(checker, "doc_files", lambda: [str(bad)])
    errors = checker.check_docs()
    assert len(errors) == 2
    assert any("not_a_real_function" in e for e in errors)
    assert any("nonexistent.py" in e for e in errors)
