"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward + one train step on CPU with finite loss
and the right shapes; decode paths agree with full-sequence forwards."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import RunConfig, SHAPES, shape_applies
from repro.data.pipeline import make_batch
from repro.models import build
from repro.train.loop import init_state, make_train_step
from repro.train.serve import generate, make_serve_step


def _smoke_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        return {
            "tokens": rng.integers(0, cfg.vocab_size, (b, s // 2)
                                   ).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (b, s // 2)
                                   ).astype(np.int32),
            "embeds": rng.standard_normal((b, s // 2, cfg.d_model)
                                          ).astype(np.float32)}
    if cfg.family == "vlm":
        txt = s - cfg.n_patches
        return {
            "tokens": rng.integers(0, cfg.vocab_size, (b, txt)
                                   ).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (b, txt)
                                   ).astype(np.int32),
            "embeds": rng.standard_normal((b, cfg.n_patches, cfg.d_model)
                                          ).astype(np.float32)}
    return {"tokens": rng.integers(0, cfg.vocab_size, (b, s)
                                   ).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (b, s)
                                   ).astype(np.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = model.forward(params, batch["tokens"],
                                embeds=batch.get("embeds"))
    b = batch["tokens"].shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite_loss(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, RunConfig()))
    batch = {k: jnp.asarray(v) for k, v in _smoke_batch(cfg).items()}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "granite-moe-1b-a400m",
                                  "xlstm-350m", "zamba2-1.2b"])
def test_loss_decreases_over_steps(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    run = RunConfig(learning_rate=3e-3, warmup_steps=1, total_steps=30)
    step = jax.jit(make_train_step(model, run))
    batch = {k: jnp.asarray(v) for k, v in _smoke_batch(cfg, b=4).items()}
    first = None
    for _ in range(8):
        state, metrics = step(state, batch)  # same batch: must memorize
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, max_len = 2, 16
    kw = {"src_len": 8} if cfg.family == "audio" else {}
    cache = model.init_cache(b, max_len, **kw)
    token = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, token, pos)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "llama3-405b",
                                  "xlstm-350m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode logits == full-sequence forward logits."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 8
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s)),
        jnp.int32)
    full_logits, _ = model.forward(params, tokens)

    cache = model.init_cache(b, s)
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        step_logits, cache = model.decode_step(params, cache,
                                               tokens[:, t], pos)
        pos = pos + 1
        # bf16 compute: the chunked-scan and one-token paths round
        # differently; ~3 bf16 ulps at logit scale still catches any real
        # misalignment (which would produce O(1) errors)
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=6e-2, atol=6e-2)


def test_generate_runs_end_to_end():
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 4), jnp.int32)
    out = generate(model, params, prompt, max_new_tokens=4)
    assert out.shape == (2, 8)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_assignment(arch):
    """The full (non-smoke) configs carry the exact published shapes."""
    cfg = get_config(arch)
    expect = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    if arch == "phi3.5-moe-42b-a6.6b":
        assert cfg.moe and (cfg.moe.n_experts, cfg.moe.top_k) == (16, 2)
    if arch == "granite-moe-1b-a400m":
        assert cfg.moe and (cfg.moe.n_experts, cfg.moe.top_k) == (32, 8)
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64


def test_shape_applicability_rules():
    long = SHAPES["long_500k"]
    ok, _ = shape_applies(get_config("xlstm-350m"), long)
    assert ok
    ok, reason = shape_applies(get_config("llama3-405b"), long)
    assert not ok and "full-attention" in reason
    # the other three shapes apply to everything
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applies(get_config(arch), SHAPES[shape])[0]


def test_make_batch_covers_all_families():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        shape = SHAPES["train_4k"]
        import dataclasses
        small = dataclasses.replace(shape, global_batch=2, seq_len=32)
        batch = make_batch(cfg, small)
        assert batch["tokens"].shape[0] == 2
        assert (batch["tokens"] < cfg.vocab_size).all()


def test_flash_attn_impl_matches_xla():
    """cfg.attn_impl='flash' routes through the Pallas kernel and matches
    the XLA chunked path at smoke scale."""
    import dataclasses
    cfg = get_smoke_config("qwen2-1.5b")
    model_xla = build(cfg)
    model_flash = build(dataclasses.replace(cfg, attn_impl="flash"))
    params = model_xla.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 128)),
        jnp.int32)
    l1, _ = model_xla.forward(params, tokens)
    l2, _ = model_flash.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=5e-2, atol=5e-2)
