"""Operation & cost synthesis (paper §3): the worked B-tree example, block
instantiation, skew, and synthesis invariants."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sweeps
    from repro.testing.hypothesis_fallback import (
        given, settings, strategies as st)

from repro.core import access, elements as el, synthesis
from repro.core.synthesis import (CostBreakdown, Workload, instantiate,
                                  synthesize_bulk_load, synthesize_get,
                                  synthesize_range_get, synthesize_update)


def test_paper_btree_example_exact():
    """§3 'Example: Cache-aware Cost Synthesis' — fanout 20, page 250,
    1e5 records, 8B keys/values: the synthesizer must log exactly
    P(312) B(152) P(6552) B(152) P(1606552) B(2000) P(2000)."""
    spec = el.spec_btree(fanout=20, page=250)
    workload = Workload(n_entries=100_000, key_bytes=8, value_bytes=8)
    cb = synthesize_get(spec, workload)
    sizes = [(rec.level1, round(rec.size)) for rec in cb.records]
    assert sizes == [
        (access.RANDOM_ACCESS, 312),
        (access.SORTED_SEARCH, 152),
        (access.RANDOM_ACCESS, 6552),
        (access.SORTED_SEARCH, 152),
        (access.RANDOM_ACCESS, 1606552),
        (access.SORTED_SEARCH, 2000),
        (access.RANDOM_ACCESS, 2000),
    ]


def test_btree_instance_geometry():
    spec = el.spec_btree(fanout=20, page=250)
    inst = instantiate(spec, Workload(n_entries=100_000))
    # 400 pages, height-2 internal hierarchy (root + 20 nodes)
    assert inst.terminal.n_nodes == 400
    assert [lvl.n_nodes for lvl in inst.levels[:-1]] == [1, 20]


def test_region_sizes_monotone_down_the_path():
    spec = el.spec_btree(fanout=20, page=250)
    inst = instantiate(spec, Workload(n_entries=1_000_000))
    regions = [lvl.region_bytes for lvl in inst.levels]
    assert all(a <= b for a, b in zip(regions, regions[1:]))


def test_sorted_leaf_uses_sorted_search_unsorted_uses_scan():
    w = Workload(n_entries=10_000)
    cb_sorted = synthesize_get(el.spec_sorted_array(10_000), w)
    assert any(r.level1 == access.SORTED_SEARCH for r in cb_sorted.records)
    cb_unsorted = synthesize_get(el.spec_array(10_000), w)
    assert any(r.level1 == access.SCAN for r in cb_unsorted.records)
    assert not any(r.level1 == access.SORTED_SEARCH
                   for r in cb_unsorted.records)


def test_hash_table_uses_hash_probe():
    cb = synthesize_get(el.spec_hash_table(), Workload(n_entries=10_000))
    assert any(r.level1 == access.HASH_PROBE for r in cb.records)


def test_bulk_load_sorts_only_sorted_structures():
    w = Workload(n_entries=10_000)
    cb = synthesize_bulk_load(el.spec_btree(), w)
    assert any(r.level1 == access.SORT for r in cb.records)
    cb = synthesize_bulk_load(el.spec_linked_list(), w)
    assert not any(r.level1 == access.SORT for r in cb.records)


def test_update_is_get_plus_write():
    w = Workload(n_entries=10_000)
    get = synthesize_get(el.spec_btree(), w)
    upd = synthesize_update(el.spec_btree(), w)
    assert len(upd.records) == len(get.records) + 1
    assert upd.records[-1].level1 == access.SERIAL_WRITE


def test_range_get_scales_with_selectivity(hw_analytical):
    spec = el.spec_btree()
    lo = synthesis.cost("range_get", spec,
                        Workload(n_entries=1_000_000, selectivity=0.001),
                        hw_analytical)
    hi = synthesis.cost("range_get", spec,
                        Workload(n_entries=1_000_000, selectivity=0.1),
                        hw_analytical)
    assert hi > lo


def test_skew_reduces_cost(hw_analytical):
    """Fig. 8b: zipfian gets are cheaper (hot paths cached)."""
    spec = el.spec_btree()
    uniform = synthesis.cost("get", spec, Workload(n_entries=1_000_000),
                             hw_analytical)
    skewed = synthesis.cost(
        "get", spec, Workload(n_entries=1_000_000, zipf_alpha=1.5),
        hw_analytical)
    assert skewed < uniform


def test_skew_helps_btree_more_than_csb(hw_analytical):
    """Fig. 8b: CSB+ improves less under skew — it is already
    cache-optimized."""
    w_uni = Workload(n_entries=1_000_000)
    w_skew = Workload(n_entries=1_000_000, zipf_alpha=1.5)
    bt_gain = (synthesis.cost("get", el.spec_btree(), w_uni, hw_analytical) /
               synthesis.cost("get", el.spec_btree(), w_skew, hw_analytical))
    csb_gain = (synthesis.cost("get", el.spec_csb_tree(), w_uni,
                               hw_analytical) /
                synthesis.cost("get", el.spec_csb_tree(), w_skew,
                               hw_analytical))
    assert bt_gain >= csb_gain * 0.99


def test_csb_cheaper_than_btree(hw_analytical):
    """Cache-conscious layout reduces traversal cost (Fig. 8a)."""
    w = Workload(n_entries=1_000_000)
    csb = synthesis.cost("get", el.spec_csb_tree(), w, hw_analytical)
    bt = synthesis.cost("get", el.spec_btree(), w, hw_analytical)
    assert csb <= bt


def test_format_matches_appendix_g1_style():
    cb = synthesize_get(el.spec_btree(fanout=20, page=250),
                        Workload(n_entries=100_000))
    text = cb.format()
    assert text.startswith("P(312)+B(152)+P(6552)")


@given(st.integers(min_value=100, max_value=10_000_000))
@settings(max_examples=30, deadline=None)
def test_cost_positive_and_monotone_in_data(n):
    """Synthesized B-tree get cost grows (weakly) with data size."""
    from repro.core.hardware import hw1
    hw = hw1()
    spec = el.spec_btree()
    small = synthesis.cost("get", spec, Workload(n_entries=n), hw)
    large = synthesis.cost("get", spec, Workload(n_entries=n * 4), hw)
    assert small > 0
    assert large >= small * 0.8  # tree height is a step function; allow 20%


@given(st.sampled_from(sorted(el.ALL_PAPER_SPECS)),
       st.sampled_from(["get", "range_get", "bulk_load", "update"]))
@settings(max_examples=60, deadline=None)
def test_every_operation_synthesizes_on_every_spec(name, op):
    import inspect
    make = el.ALL_PAPER_SPECS[name]
    sig = inspect.signature(make)
    spec = make(10_000) if "n_puts" in sig.parameters else make()
    cb = synthesis.synthesize_operation(op, spec, Workload(n_entries=10_000))
    assert cb.records
    assert all(rec.size >= 1.0 and rec.count > 0 for rec in cb.records)
