"""Cache-key invariants of the pack->score pipeline.

The memo map and its two invariants are documented in
``docs/cost_pipeline.md``:

1. **Hardware never appears in a synthesis/packing key** — a what-if-
   hardware question re-scores retained frontiers as a pure device
   parameter-table swap.
2. **Workload never appears in a template-statics key** (PR 5) — a
   workload sweep re-derives only numeric geometry columns; structure,
   schemas and model-id layouts are shared across every sweep point.

Rather than trusting comments, these tests exercise every packing layer
and then *walk the actual keys* of every registered cache
(:func:`repro.core.memo.registered_caches`).
"""
import dataclasses

import numpy as np

from repro.core import batchcost, elements as el
from repro.core.hardware import HardwareProfile, hw3
from repro.core.memo import registered_caches
from repro.core.synthesis import Workload

#: caches whose keys must be workload-free (the template-statics layer)
STATICS_CACHES = ("chain_statics", "segment_statics")


def _walk(obj):
    yield obj
    if isinstance(obj, (tuple, list, frozenset)):
        for item in obj:
            yield from _walk(item)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk(k)
            yield from _walk(v)


def _exercise_every_layer(hw) -> None:
    w1 = Workload(n_entries=96_000)
    w2 = dataclasses.replace(w1, zipf_alpha=1.3)
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list()]
    batchcost.cost_many(specs, w1, hw, {"get": 8.0, "update": 2.0})
    batchcost.cost_sweep(specs, [w1, w2], hw,
                         [{"get": 10.0}, {"get": 5.0, "update": 5.0}])
    batchcost.pack_frontier(specs, w2, None)


def test_registered_caches_cover_the_packing_stack(hw_analytical):
    """The introspection registry must actually see the packing layers —
    an unregistered (hence unaudited) cache would silently exempt itself
    from the invariants below."""
    batchcost.clear_caches()
    _exercise_every_layer(hw_analytical)
    caches = registered_caches()
    for name in ("packed_spec", "frontier", "sweep") + STATICS_CACHES:
        assert name in caches, name
        assert caches[name].keys(), f"{name} was never populated"


def test_hardware_never_in_any_cache_key(hw_analytical):
    batchcost.clear_caches()
    _exercise_every_layer(hw_analytical)
    for name, cache in registered_caches().items():
        for key in cache.keys():
            for node in _walk(key):
                assert not isinstance(node, HardwareProfile), \
                    f"HardwareProfile inside {name} key {key!r}"


def test_workload_never_in_template_statics_keys(hw_analytical):
    batchcost.clear_caches()
    _exercise_every_layer(hw_analytical)
    caches = registered_caches()
    for name in STATICS_CACHES:
        for key in caches[name].keys():
            for node in _walk(key):
                assert not isinstance(node, Workload), \
                    f"Workload inside {name} key {key!r}"


def test_statics_entries_shared_across_workloads(hw_analytical):
    """Behavioral form of invariant 2: N same-structure workloads over
    one chain set leave exactly one statics entry per chain."""
    batchcost.clear_caches()
    base = Workload(n_entries=80_000)
    workloads = [dataclasses.replace(base, zipf_alpha=a, n_queries=q)
                 for a, q in ((0.0, 100), (0.7, 100), (1.4, 500),
                              (2.0, 50))]
    specs = [el.spec_btree(), el.spec_trie()]
    batchcost.cost_sweep(specs, workloads, hw_analytical)
    info = batchcost.cache_info()
    assert info["chain_statics"].currsize == len(specs)
    assert info["segment_statics"].currsize <= len(specs)


def test_sweep_scoring_touches_no_packing_cache(hw_analytical,
                                                cpu_profile):
    """Invariant 1 for the sweep product: scoring one retained sweep on a
    second profile touches no packing layer at all (pure table swap)."""
    batchcost.clear_caches()
    w = Workload(n_entries=64_000)
    sweep = batchcost.pack_sweep(
        [el.spec_btree(), el.spec_hash_table()],
        [w, dataclasses.replace(w, zipf_alpha=1.1)])
    before = {k: (v.hits, v.misses)
              for k, v in batchcost.cache_info().items()}
    a = sweep.score(hw_analytical)
    b = sweep.score(cpu_profile)
    assert {k: (v.hits, v.misses)
            for k, v in batchcost.cache_info().items()} == before
    assert a.shape == b.shape == (2, 2)
