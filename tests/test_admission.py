"""Production traffic hardening: admission control, priority lanes,
deadlines, overload shedding, cancellation, and shutdown semantics.

Scheduler/bucket mechanics are tested pure (no scoring); service-level
behavior runs real questions through a live worker.  The contract under
test is docs/serving.md's: overload *rejects* (typed, immediately —
never blocks, never deadlocks), bulk traffic cannot starve interactive
questions, deadlines fail fast, and shutdown is distinguishable from
shedding."""
import threading
import time

import pytest

from repro.core import elements as el
from repro.core.hardware import hw1, hw2
from repro.core.synthesis import Workload
from repro.serving import (BULK, INTERACTIVE, BudgetExceeded,
                           DeadlineExceeded, DesignCalculatorService,
                           LaneScheduler, RejectedError, ServiceStoppedError,
                           SessionBudgets, TokenBucket, request_cost)
from repro.serving.lanes import CLOSED
from repro.serving.service import _Evaluation, _Request

pytestmark = pytest.mark.load

W = Workload(n_entries=100_000, n_queries=100)


# ---------------------------------------------------------------------------
# Cost pricing and token buckets (pure)
# ---------------------------------------------------------------------------
def test_request_cost_is_cells():
    assert request_cost(2) == 2.0
    assert request_cost(64, 8) == 512.0
    # degenerate sizes still price at one cell
    assert request_cost(0) == 1.0
    assert request_cost(0, 0) == 1.0


def test_token_bucket_burst_and_refill():
    clock = [0.0]
    bucket = TokenBucket(capacity=10, refill_per_s=5,
                         clock=lambda: clock[0])
    assert bucket.try_acquire(8)
    assert not bucket.try_acquire(4)      # 2 left
    clock[0] = 1.0                        # +5 tokens
    assert bucket.available() == pytest.approx(7.0)
    assert bucket.try_acquire(7)
    clock[0] = 100.0                      # refill caps at capacity
    assert bucket.available() == pytest.approx(10.0)


def test_token_bucket_rejects_bad_config():
    with pytest.raises(ValueError):
        TokenBucket(capacity=0, refill_per_s=1)
    with pytest.raises(ValueError):
        TokenBucket(capacity=1, refill_per_s=0)


def test_session_budgets_are_isolated():
    clock = [0.0]
    budgets = SessionBudgets(capacity=4, refill_per_s=0.001,
                             clock=lambda: clock[0])
    budgets.admit("alice", 4)
    with pytest.raises(BudgetExceeded) as exc:
        budgets.admit("alice", 4)         # alice is dry...
    assert exc.value.session == "alice"
    assert exc.value.cost == 4
    budgets.admit("bob", 4)               # ...bob is unaffected
    # sessionless traffic shares one anonymous bucket
    budgets.admit(None, 4)
    with pytest.raises(BudgetExceeded) as exc:
        budgets.admit(None, 1)
    assert exc.value.session == SessionBudgets.ANONYMOUS
    # BudgetExceeded is a RejectedError: one handler catches both sheds
    assert issubclass(BudgetExceeded, RejectedError)


# ---------------------------------------------------------------------------
# Lane scheduler (pure)
# ---------------------------------------------------------------------------
def test_lane_overflow_rejects_immediately_not_deadlocks():
    sched = LaneScheduler(capacities={INTERACTIVE: 2, BULK: 1})
    assert sched.put("i1") == 0
    assert sched.put("i2") == 1
    t0 = time.monotonic()
    with pytest.raises(RejectedError) as exc:
        sched.put("i3")                   # full lane must shed NOW
    assert time.monotonic() - t0 < 0.5
    assert exc.value.lane == INTERACTIVE
    assert exc.value.depth == 2 and exc.value.limit == 2
    sched.put("b1", BULK)
    with pytest.raises(RejectedError):
        sched.put("b2", BULK)
    # the full lanes drained normally afterwards
    assert [sched.get(0.1) for _ in range(3)].count(None) == 0


def test_weighted_round_robin_and_priority():
    sched = LaneScheduler(weights={INTERACTIVE: 2, BULK: 1})
    for i in range(4):
        sched.put(f"i{i}")
    for i in range(4):
        sched.put(f"b{i}", BULK)
    order = [sched.get(0.1) for _ in range(8)]
    # 2 interactive : 1 bulk while both lanes hold work
    assert order[:6] == ["i0", "i1", "b0", "i2", "i3", "b1"]
    # interactive drained: bulk flows at full rate
    assert order[6:] == ["b2", "b3"]


def test_bulk_flood_cannot_starve_interactive():
    sched = LaneScheduler()
    for i in range(50):
        sched.put(f"b{i}", BULK)
    sched.put("urgent")
    # the interactive arrival is served ahead of the 50-deep bulk backlog
    assert sched.get(0.1) == "urgent"


def test_restricted_get_skips_other_lanes():
    sched = LaneScheduler()
    sched.put("b0", BULK)
    # only-bulk queued + interactive-only request -> timeout, not bulk
    assert sched.get(0.05, lanes=(INTERACTIVE,)) is None
    sched.put("i0")
    assert sched.get(0.05, lanes=(INTERACTIVE,)) == "i0"
    assert sched.get(0.05) == "b0"


def test_close_sheds_then_drains_then_reports_closed():
    sched = LaneScheduler()
    sched.put("i0")
    sched.put("b0", BULK)
    sched.close()
    with pytest.raises(ServiceStoppedError) as exc:
        sched.put("i1")
    assert exc.value.queue_position == 1   # behind i0
    # queued work still drains after close, then CLOSED
    assert sched.get(0.1) == "i0"
    assert sched.get(0.1) == "b0"
    assert sched.get(0.1) is CLOSED
    # a restricted get never reports CLOSED while other lanes hold work
    sched.reopen()
    sched.put("b1", BULK)
    sched.close()
    assert sched.get(0.05, lanes=(INTERACTIVE,)) is None
    assert sched.get(0.05) == "b1"


def test_drain_reports_positions():
    sched = LaneScheduler()
    for name in ("i0", "i1"):
        sched.put(name)
    sched.put("b0", BULK)
    drained = sched.drain()
    assert drained == [("i0", INTERACTIVE, 0), ("i1", INTERACTIVE, 1),
                       ("b0", BULK, 0)]
    assert sched.depth() == 0


# ---------------------------------------------------------------------------
# Service-level behavior (live worker)
# ---------------------------------------------------------------------------
def _svc(*hws, **kwargs):
    kwargs.setdefault("window_s", 0.002)
    return DesignCalculatorService(list(hws), **kwargs)


def test_budget_exhaustion_sheds_at_submit():
    h1 = hw1()
    svc = _svc(h1, budget_cells=2, budget_refill_per_s=1e-6)
    try:
        spec, variant = el.spec_btree(), el.spec_btree(fanout=40)
        svc.what_if_design(spec, variant, W, h1)       # 2 cells: admitted
        with pytest.raises(BudgetExceeded):
            svc.what_if_design(spec, variant, W, h1)   # bucket is dry
        stats = svc.stats()
        assert stats["budget_rejected"] == 1
        assert stats["answered"] == 1
    finally:
        svc.stop()


def test_zero_capacity_bulk_lane_sheds_sweeps_but_serves_whatifs():
    h1 = hw1()
    svc = _svc(h1, bulk_capacity=0)
    try:
        with pytest.raises(RejectedError) as exc:
            svc.submit_sweep([el.spec_btree()], [W], h1)
        assert exc.value.lane == BULK
        spec, variant = el.spec_btree(), el.spec_btree(fanout=40)
        answer = svc.what_if_design(spec, variant, W, h1)
        assert answer.baseline_seconds > 0
        stats = svc.stats()
        assert stats["shed_bulk"] == 1 and stats["shed_interactive"] == 0
    finally:
        svc.stop()


def test_expired_deadline_fails_fast_with_deadline_exceeded():
    h1 = hw1()
    svc = _svc(h1)
    try:
        spec, variant = el.spec_btree(), el.spec_btree(fanout=40)
        svc.what_if_design(spec, variant, W, h1)       # warm the caches
        fut = svc.submit_design(spec, variant, W, h1, deadline_s=0.0)
        with pytest.raises(DeadlineExceeded) as exc:
            fut.result(timeout=30)
        assert exc.value.late_by_s >= 0.0
        assert svc.stats()["expired"] == 1
    finally:
        svc.stop()


def test_deadline_rechecked_between_scoring_groups(monkeypatch):
    """A request that expires while an earlier group scores is failed at
    the between-groups check, not served late.  Driven deterministically
    through ``_serve_batch`` with a scripted clock."""
    import concurrent.futures

    from repro.serving import service as service_mod

    h1, h2 = hw1(), hw2()
    svc = DesignCalculatorService([h1, h2], start=False)
    spec = el.spec_btree()
    ev1 = _Evaluation((spec,), W, None, h1.name)
    ev2 = _Evaluation((spec,), W, None, h2.name)
    fut = concurrent.futures.Future()
    # expires at t=50: alive at batch assembly (t=0), dead by the time
    # the second group is reached (t=100)
    req = _Request([ev1, ev2], lambda elapsed: (ev1.totals, ev2.totals),
                   fut, 0.0, deadline=50.0, deadline_s=50.0)
    ticks = iter([0.0, 100.0, 100.0, 100.0])
    real = time.monotonic
    monkeypatch.setattr(service_mod.time, "monotonic",
                        lambda: next(ticks, real()))
    svc._serve_batch([req])
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0)
    assert svc.stats()["expired"] == 1


def test_cancel_before_serving_skips_scoring():
    import concurrent.futures

    h1 = hw1()
    svc = DesignCalculatorService([h1], start=False)
    ev = _Evaluation((el.spec_btree(),), W, None, h1.name)
    fut = concurrent.futures.Future()
    req = _Request([ev], lambda elapsed: ev.totals, fut, 0.0)
    assert fut.cancel()
    svc._serve_batch([req])
    assert ev.packed is None               # never packed, never scored
    assert svc.stats()["cancelled"] == 1


def test_stop_fails_stragglers_with_queue_position():
    import concurrent.futures

    h1 = hw1()
    svc = DesignCalculatorService([h1], start=False)
    futs = [concurrent.futures.Future() for _ in range(3)]
    for i, fut in enumerate(futs):
        ev = _Evaluation((el.spec_btree(),), W, None, h1.name)
        svc._sched.put(_Request([ev], lambda e: None, fut, 0.0))
    svc._fail_pending()
    for i, fut in enumerate(futs):
        with pytest.raises(ServiceStoppedError) as exc:
            fut.result(timeout=0)
        assert exc.value.queue_position == i
    assert svc.stats()["stopped_requests"] == 3


def test_submit_during_shutdown_gets_service_stopped_error():
    h1 = hw1()
    svc = _svc(h1)
    spec, variant = el.spec_btree(), el.spec_btree(fanout=40)
    svc.what_if_design(spec, variant, W, h1)
    svc._sched.close()                     # shutdown has begun
    with pytest.raises(ServiceStoppedError):
        svc.submit_design(spec, variant, W, h1)
    assert svc.stats()["stopped_requests"] == 1
    svc.stop()


def test_interactive_answers_resolve_before_bulk_groups():
    """With lanes on, an interactive future must resolve even though a
    bulk sweep shares (and dominates) its coalescing window."""
    h1 = hw1()
    specs = [el.spec_btree(fanout=8 + 2 * i) for i in range(16)]
    workloads = [W, Workload(n_entries=100_000, n_queries=100,
                             zipf_alpha=1.0)]
    svc = _svc(h1, window_s=0.05)
    try:
        spec, variant = el.spec_btree(), el.spec_btree(fanout=40)
        svc.what_if_design(spec, variant, W, h1)        # warm + compile
        svc.workload_sweep(specs, workloads, h1)
        sweep_fut = svc.submit_sweep(specs, workloads, h1)
        what_fut = svc.submit_design(spec, variant, W, h1)
        what_fut.result(timeout=30)
        sweep_fut.result(timeout=30)
        stats = svc.stats()
        assert stats["failed"] == 0
        assert stats["answered"] >= 4
    finally:
        svc.stop()


def test_lane_routing_by_request_kind():
    h1 = hw1()
    svc = _svc(h1, bulk_threshold=2, bulk_capacity=0)
    try:
        # a >=2-design completion routes to the (zero-capacity) bulk lane
        with pytest.raises(RejectedError):
            svc.submit_complete((el.spec_btree().chain[0],), W, h1,
                                max_depth=2)
        # explicit lane override forces it back to interactive
        res = svc.complete_design((el.spec_btree().chain[0],), W, h1,
                                  max_depth=2, lane=INTERACTIVE)
        assert res.explored >= 2
    finally:
        svc.stop()
