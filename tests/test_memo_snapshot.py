"""Warm-restart snapshots: round-trip fidelity, model-id remapping, and
the never-crash-on-bad-snapshot contract (docs/serving.md).

A snapshot is an optimization, not state the service depends on — so
the failure contract is the interesting part: a corrupt, truncated,
stale or missing snapshot must restore *nothing* (cold start) and must
never crash ``DesignCalculatorService.start()``."""
import dataclasses
import os
import pickle

import numpy as np
import pytest

from repro.core import batchcost, devicecost, memo
from repro.core import elements as el
from repro.core.hardware import hw1
from repro.core.synthesis import Workload
from repro.serving import DesignCalculatorService

W = Workload(n_entries=100_000, n_queries=100)
SKEWED = dataclasses.replace(W, zipf_alpha=1.0)

SPECS = (el.spec_btree(), el.spec_btree(fanout=40),
         el.spec_hash_table(), el.spec_skip_list())


def _warm(hw):
    """Populate every snapshotted cache layer and return oracle totals."""
    flat = batchcost.pack_frontier(SPECS, W)
    sweep = batchcost.pack_sweep(SPECS, [W, SKEWED])
    return flat.score(hw), sweep.score(hw)


def test_snapshot_roundtrip_restores_warm_packing(tmp_path, hw_analytical):
    snap = str(tmp_path / "memo.snap")
    flat_totals, sweep_grid = _warm(hw_analytical)
    written = memo.snapshot_caches(snap)
    assert written > 0
    batchcost.clear_caches()
    assert memo.restore_caches(snap) == written

    # re-packing must be a pure cache hit — zero frontier/sweep misses
    flat = batchcost.pack_frontier(SPECS, W)
    sweep = batchcost.pack_sweep(SPECS, [W, SKEWED])
    for name in ("frontier", "sweep"):
        info = memo.REGISTRY[name].info()
        assert info.misses == 0, f"{name} cache missed after restore"
        assert info.hits >= 1
    # and the restored products score identically
    np.testing.assert_allclose(flat.score(hw_analytical), flat_totals,
                               rtol=1e-12)
    np.testing.assert_allclose(sweep.score(hw_analytical), sweep_grid,
                               rtol=1e-12)


def test_restored_rectangular_sweep_keeps_ids_aliased(tmp_path,
                                                      hw_analytical):
    """Rectangular sweeps share ONE interned-ids array across points —
    the property the one-call ``score_sweep`` fast path keys on.  The
    id-remap on restore must preserve that sharing, not fan the alias
    out into per-point copies."""
    snap = str(tmp_path / "memo.snap")
    _warm(hw_analytical)
    memo.snapshot_caches(snap)
    batchcost.clear_caches()
    assert memo.restore_caches(snap) > 0
    restored = [value for _, value in memo.REGISTRY["sweep"].items()]
    assert restored
    for sweep in restored:
        assert sweep.rectangular
        assert all(f.ids is sweep.frontiers[0].ids
                   for f in sweep.frontiers)


def test_snapshot_strips_device_state(tmp_path, hw_analytical):
    """Scored sweeps memoize device-resident arrays on ``__dict__`` —
    capture must strip them or the pickle drags live buffers along."""
    snap = str(tmp_path / "memo.snap")
    _warm(hw_analytical)                       # scoring populates _f32
    memo.snapshot_caches(snap)
    with open(snap, "rb") as fh:
        payload = pickle.load(fh)
    for items in payload["caches"].values():
        for _, value in items:
            assert "_f32" not in getattr(value, "__dict__", {})


def test_restore_remaps_model_ids():
    """Ids are interned lazily in first-use order, so a fresh process
    interns in a different order than the one that snapshotted.  The
    remap array must send each snapshot-order id to the live id of the
    same model name."""
    batchcost.pack_frontier(SPECS, W)          # ensure names are interned
    names = devicecost._capture_model_names()
    assert len(names) >= 2
    remap = devicecost._restore_model_remap(list(reversed(names)))
    live = devicecost._capture_model_names()
    for old_id, name in enumerate(reversed(names)):
        assert live[remap[old_id]] == name


@pytest.mark.parametrize("corruption", ["missing", "garbage", "truncated",
                                        "stale_version"])
def test_bad_snapshot_restores_nothing(tmp_path, hw_analytical, monkeypatch,
                                       corruption):
    snap = str(tmp_path / "memo.snap")
    if corruption == "garbage":
        with open(snap, "wb") as fh:
            fh.write(b"\x00not a pickle\xff" * 64)
    elif corruption == "truncated":
        _warm(hw_analytical)
        memo.snapshot_caches(snap)
        size = os.path.getsize(snap)
        with open(snap, "r+b") as fh:
            fh.truncate(size // 2)
    elif corruption == "stale_version":
        _warm(hw_analytical)
        memo.snapshot_caches(snap)
        monkeypatch.setattr(memo, "SNAPSHOT_SCHEMA", 999)
    # "missing": never created
    batchcost.clear_caches()
    assert memo.restore_caches(snap) == 0
    for name in ("frontier", "sweep", "packed_spec"):
        assert memo.REGISTRY[name].info().currsize == 0


@pytest.mark.parametrize("corruption", ["garbage", "truncated"])
def test_service_start_survives_bad_snapshot(tmp_path, corruption):
    snap = str(tmp_path / "memo.snap")
    hw = hw1()
    if corruption == "garbage":
        with open(snap, "wb") as fh:
            fh.write(os.urandom(512))
    else:
        keeper = DesignCalculatorService([hw], start=False)
        keeper.save_snapshot(snap)
        with open(snap, "r+b") as fh:
            fh.truncate(max(os.path.getsize(snap) // 2, 1))
    svc = DesignCalculatorService([hw], snapshot_path=snap)
    try:
        assert svc.stats()["snapshot_entries"] == 0    # cold, not crashed
        answer = svc.what_if_design(el.spec_btree(), el.spec_btree(fanout=40),
                                    W, hw)
        assert answer.baseline_seconds > 0
    finally:
        svc.stop()


def test_service_snapshot_roundtrip_end_to_end(tmp_path):
    snap = str(tmp_path / "memo.snap")
    hw = hw1()
    with DesignCalculatorService([hw], snapshot_path=snap) as svc:
        cold = svc.workload_sweep(list(SPECS), [W, SKEWED], hw)
        svc.save_snapshot()
    batchcost.clear_caches()
    with DesignCalculatorService([hw], snapshot_path=snap) as svc:
        assert svc.stats()["snapshot_entries"] > 0
        warm = svc.workload_sweep(list(SPECS), [W, SKEWED], hw)
        info = memo.REGISTRY["sweep"].info()
        assert info.misses == 0                # the sweep came from disk
    np.testing.assert_allclose(np.asarray(warm.totals),
                               np.asarray(cold.totals), rtol=1e-12)
