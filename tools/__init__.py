"""Repo tooling: the static-analysis framework lives in tools.analyze."""
