"""repro-lint: AST/dataflow checks for the repo's core invariants.

Library API::

    from tools.analyze import run_paths, Finding
    findings = run_paths(["src/repro", "benchmarks", "tools"])

CLI (exits nonzero on findings)::

    python -m tools.analyze [paths...] [--format json] [--checker NAME]

Checkers: cache-keys (hardware/workload cache-key purity), locks
(memo/serving lock discipline), futures (submitted-future hygiene),
jit-safety (tracer-safety of jit/pmap-reachable code), docs-refs
(documentation references resolve).  See docs/static_analysis.md.
"""
from tools.analyze.core import (DEFAULT_PATHS, Finding, render_json,
                                render_text, run_paths)

__all__ = ["DEFAULT_PATHS", "Finding", "render_json", "render_text",
           "run_paths"]
