"""Shared AST/dataflow machinery for the repro-lint checkers.

Everything here is *intraprocedural* and deliberately conservative in
the same direction for every checker: taint over-approximates (any
expression mentioning a tainted name is tainted unless the mention is
syntactically sanitized), lock dominance under-approximates (only a
lexically enclosing ``with <lock>:`` counts).  Checkers that need
cross-function facts build small per-module summaries on top (the
future-hygiene checker's "returns a future" fixpoint, the jit checker's
same-module callee walk) — never whole-program analysis.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple


def build_parents(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    """child node -> parent node, for upward walks."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_functions(tree: ast.AST) -> Iterable[ast.FunctionDef]:
    """Every function/method/nested def in the module, in source order."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def module_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Top-level functions by name."""
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def param_names(func: ast.FunctionDef) -> List[str]:
    a = func.args
    names = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        names.append(a.vararg.arg)
    names.extend(p.arg for p in a.kwonlyargs)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def own_statements(func: ast.FunctionDef) -> Iterable[ast.AST]:
    """The function's own statements, NOT descending into nested defs
    or lambdas (their locals shadow; checkers analyze them separately)."""
    stack: List[ast.AST] = list(func.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


def assigned_names(func: ast.FunctionDef) -> Set[str]:
    """Every name the function binds (assignment targets, loop vars,
    with-as, comprehension targets, nested def/class names)."""
    out: Set[str] = set(param_names(func))
    for node in own_statements(func):
        if isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                out.add((alias.asname or alias.name).split(".")[0])
    return out


# ---------------------------------------------------------------------------
# Taint
# ---------------------------------------------------------------------------
#: attribute reads that launder a traced/tainted value into static shape
#: metadata — ``x.shape[0]`` is a Python int inside a jitted trace
SHAPE_ATTRS = frozenset({"shape", "dtype", "ndim", "size"})

#: calls whose result is static even on tainted arguments
SHAPE_CALLS = frozenset({
    "len", "isinstance", "type", "id",
    "jnp.issubdtype", "np.issubdtype", "jnp.iinfo", "jnp.finfo",
    "np.iinfo", "np.finfo", "jnp.shape", "np.shape", "jnp.result_type",
})


class Taint:
    """Forward intraprocedural taint over one function body.

    Iterated to fixpoint over the function's own assignments (flow
    insensitive: an assignment anywhere taints the name everywhere —
    the conservative direction for invariant checking).
    """

    def __init__(self, func: ast.FunctionDef, seeds: Set[str],
                 sanitize_shapes: bool = False) -> None:
        self.func = func
        self.tainted: Set[str] = set(seeds)
        self.sanitize_shapes = sanitize_shapes
        self._parents = build_parents(func)
        self._fixpoint()

    def _fixpoint(self) -> None:
        changed = True
        while changed:
            changed = False
            for node in own_statements(self.func):
                targets: List[ast.expr] = []
                value: Optional[ast.expr] = None
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.NamedExpr):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.For):
                    targets, value = [node.target], node.iter
                elif isinstance(node, ast.withitem) and node.optional_vars:
                    targets = [node.optional_vars]
                    value = node.context_expr
                elif isinstance(node, ast.comprehension):
                    targets, value = [node.target], node.iter
                if value is None or not self.expr_tainted(value):
                    continue
                for t in targets:
                    for name in self._target_names(t):
                        if name not in self.tainted:
                            self.tainted.add(name)
                            changed = True

    @staticmethod
    def _target_names(target: ast.expr) -> Iterable[str]:
        if isinstance(target, ast.Name):
            yield target.id
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                yield from Taint._target_names(el)
        elif isinstance(target, ast.Starred):
            yield from Taint._target_names(target.value)

    def _sanitized(self, name_node: ast.Name) -> bool:
        """True when this mention of a tainted name is laundered through
        shape metadata (``x.shape``) or a shape-of call (``len(x)``)."""
        if not self.sanitize_shapes:
            return False
        node: ast.AST = name_node
        while True:
            parent = self._parents.get(node)
            if parent is None:
                return False
            if isinstance(parent, ast.Attribute) and parent.value is node:
                return parent.attr in SHAPE_ATTRS
            if isinstance(parent, ast.Subscript) and parent.value is node:
                node = parent          # x[0].shape still sanitizes
                continue
            if isinstance(parent, ast.Call) and node in parent.args:
                callee = dotted(parent.func)
                if callee in SHAPE_CALLS:
                    return True
                return False
            return False

    def expr_tainted(self, expr: ast.expr) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in self.tainted \
                    and isinstance(node.ctx, ast.Load) \
                    and not self._sanitized(node):
                return True
        return False


# ---------------------------------------------------------------------------
# Lock dominance
# ---------------------------------------------------------------------------
def under_lock(node: ast.AST, parents: Dict[ast.AST, ast.AST],
               lock_names: Set[str]) -> bool:
    """True when ``node`` sits lexically inside ``with <lock>:`` for any
    lock in ``lock_names`` (dotted names, e.g. ``{"self._lock",
    "MEMO_LOCK", "memo.MEMO_LOCK"}``)."""
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, (ast.With, ast.AsyncWith)):
            for item in cur.items:
                name = dotted(item.context_expr)
                if name in lock_names:
                    return True
        cur = parents.get(cur)
    return False


def enclosing_function(node: ast.AST, parents: Dict[ast.AST, ast.AST]
                       ) -> Optional[ast.FunctionDef]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def call_keywords(call: ast.Call) -> Dict[str, ast.expr]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg}


def const_str_tuple(node: ast.expr) -> Optional[Tuple[str, ...]]:
    """A literal str or tuple/list of str constants, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, str)):
                return None
            out.append(el.value)
        return tuple(out)
    return None


def const_int_tuple(node: ast.expr) -> Optional[Tuple[int, ...]]:
    """A literal int or tuple/list of int constants, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, int)):
                return None
            out.append(el.value)
        return tuple(out)
    return None
