"""repro-lint core: findings, suppressions, baseline, and the runner.

The framework is deliberately small: a checker is a module exposing

* ``NAME`` — the checker's id (``--checker`` filter, finding prefix);
* ``RULES`` — ``{rule: one-line description}`` of every rule it emits;
* ``check_module(mod)`` — per-file entry point taking a
  :class:`ModuleRecord` and yielding :class:`Finding`s; and/or
* ``check_repo(root)`` — run once per invocation (repo-wide checkers,
  e.g. the docs-reference audit).

Findings carry ``path:line`` anchors relative to the repo root.  A
finding is silenced by a *documented* suppression comment on (or
directly above) its line::

    self._pool.shutdown(wait=False)   # lint: unlocked(close is owner-only)

The grammar is ``# lint: <rule>(<reason>)`` — the rule must be the exact
rule id and the reason must be non-empty (a suppression with an empty
reason is itself reported, so suppressions can't rot into unexplained
noise).  A suppression comment on its own line applies to the next line.

The checked-in baseline (``tools/analyze/baseline.json``) is a list of
``{checker, rule, path, message}`` entries subtracted from the report —
it ships **empty**: real violations get fixed, not grandfathered.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: repo root — tools/analyze/core.py -> tools/analyze -> tools -> root
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

#: default analysis surface (mirrors the CI invocation)
DEFAULT_PATHS = ("src/repro", "benchmarks", "tools")

#: machine-readable report schema version (bump on breaking changes)
REPORT_VERSION = 1

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*([A-Za-z0-9_-]+)\(([^)]*)\)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, anchored at ``path:line``."""

    path: str       # repo-relative, forward slashes
    line: int
    checker: str
    rule: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: [{self.checker}/{self.rule}] "
                f"{self.message}")

    def to_dict(self) -> Dict:
        return {"path": self.path, "line": self.line,
                "checker": self.checker, "rule": self.rule,
                "message": self.message}

    def baseline_key(self) -> tuple:
        # line numbers shift too easily to key a baseline on
        return (self.checker, self.rule, self.path, self.message)


class ModuleRecord:
    """One parsed source file handed to every file-scope checker."""

    def __init__(self, path: str, relpath: str, text: str,
                 tree: ast.Module) -> None:
        self.path = path          # absolute
        self.relpath = relpath    # repo-relative, forward slashes
        self.text = text
        self.tree = tree
        #: line -> set of rule ids suppressed there (next-line comments
        #: already folded onto the line they govern)
        self.suppressions: Dict[int, Set[str]] = {}
        #: malformed suppressions (empty reason) found while scanning
        self.bad_suppressions: List[Finding] = []
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        lines = self.text.splitlines()
        for lineno, line in enumerate(lines, start=1):
            for m in _SUPPRESS_RE.finditer(line):
                rule, reason = m.group(1), m.group(2).strip()
                if not reason:
                    self.bad_suppressions.append(Finding(
                        self.relpath, lineno, "framework",
                        "bare-suppression",
                        f"suppression for {rule!r} has no reason — "
                        f"write '# lint: {rule}(<why it is safe>)'"))
                    continue
                target = lineno
                if line[:m.start()].strip() == "":
                    target = lineno + 1   # comment-only line: govern next
                self.suppressions.setdefault(target, set()).add(rule)

    def suppressed(self, rule: str, line: int) -> bool:
        return rule in self.suppressions.get(line, ())


def _iter_py_files(paths: Sequence[str], root: str) -> List[str]:
    out: List[str] = []
    for p in paths:
        absp = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(absp):
            out.append(absp)
        elif os.path.isdir(absp):
            for dirpath, dirnames, filenames in os.walk(absp):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                out.extend(os.path.join(dirpath, f)
                           for f in sorted(filenames)
                           if f.endswith(".py"))
        else:
            raise FileNotFoundError(f"no such path: {p}")
    return sorted(set(out))


def load_module(path: str, root: str = ROOT) -> Optional[ModuleRecord]:
    """Parse one file into a :class:`ModuleRecord` (None on syntax error
    — reported by the runner as a framework finding, not a crash)."""
    with tokenize.open(path) as fh:
        text = fh.read()
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    tree = ast.parse(text, filename=rel)
    return ModuleRecord(path, rel, text, tree)


def load_baseline(path: Optional[str]) -> Set[tuple]:
    if path is None or not os.path.exists(path):
        return set()
    with open(path) as fh:
        entries = json.load(fh)
    return {(e["checker"], e["rule"], e["path"], e["message"])
            for e in entries}


def run_paths(paths: Sequence[str] = DEFAULT_PATHS, *,
              root: str = ROOT,
              checkers: Optional[Sequence] = None,
              baseline: Optional[str] = "default") -> List[Finding]:
    """Run checkers over ``paths``; returns sorted, unsuppressed findings.

    ``checkers`` is a sequence of checker modules (default: all
    registered in :mod:`tools.analyze.checkers`); ``baseline`` is a path
    to a baseline JSON, ``"default"`` for the checked-in one, or ``None``
    for no baseline.
    """
    if checkers is None:
        from tools.analyze.checkers import ALL_CHECKERS
        checkers = ALL_CHECKERS
    if baseline == "default":
        baseline = os.path.join(ROOT, "tools", "analyze", "baseline.json")
    findings: List[Finding] = []
    files = _iter_py_files(paths, root)
    file_checkers = [c for c in checkers if hasattr(c, "check_module")]
    repo_checkers = [c for c in checkers if hasattr(c, "check_repo")]
    for path in files:
        try:
            mod = load_module(path, root)
        except SyntaxError as exc:
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            findings.append(Finding(rel, exc.lineno or 0, "framework",
                                    "syntax-error", str(exc.msg)))
            continue
        findings.extend(mod.bad_suppressions)
        for checker in file_checkers:
            for f in checker.check_module(mod):
                if not mod.suppressed(f.rule, f.line):
                    findings.append(f)
    for checker in repo_checkers:
        findings.extend(checker.check_repo(root))
    known = load_baseline(baseline)
    findings = [f for f in findings if f.baseline_key() not in known]
    return sorted(set(findings))


def render_text(findings: Iterable[Finding]) -> str:
    findings = list(findings)
    lines = [f.format() for f in findings]
    lines.append(f"repro-lint: {len(findings)} finding"
                 f"{'' if len(findings) == 1 else 's'}")
    return "\n".join(lines)


def render_json(findings: Iterable[Finding]) -> str:
    findings = list(findings)
    return json.dumps({"version": REPORT_VERSION,
                       "count": len(findings),
                       "findings": [f.to_dict() for f in findings]},
                      indent=2, sort_keys=True)
