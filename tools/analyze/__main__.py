"""CLI: ``python -m tools.analyze [paths...] [--format json] ...``

Exit status 0 when the tree lints clean, 1 when any finding survives
suppressions and the baseline, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys

from tools.analyze.core import (DEFAULT_PATHS, render_json, render_text,
                                run_paths)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="repro-lint: static checks for the repo's "
                    "concurrency, cache-key and jit-safety invariants")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help=f"files/directories to analyze (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="report format (json is stable, versioned)")
    ap.add_argument("--checker", action="append", default=None,
                    metavar="NAME",
                    help="run only this checker (repeatable)")
    ap.add_argument("--baseline", default="default",
                    help="baseline JSON to subtract ('none' to disable)")
    ap.add_argument("--list", action="store_true",
                    help="list checkers and rules, then exit")
    args = ap.parse_args(argv)

    from tools.analyze.checkers import ALL_CHECKERS, BY_NAME
    if args.list:
        for c in ALL_CHECKERS:
            print(f"{c.NAME}:")
            for rule, desc in c.RULES.items():
                print(f"  {rule}: {desc}")
        return 0
    checkers = ALL_CHECKERS
    if args.checker:
        unknown = [n for n in args.checker if n not in BY_NAME]
        if unknown:
            ap.error(f"unknown checker(s) {unknown}; "
                     f"choose from {sorted(BY_NAME)}")
        checkers = [BY_NAME[n] for n in args.checker]
    baseline = None if args.baseline == "none" else args.baseline
    findings = run_paths(args.paths, checkers=checkers, baseline=baseline)
    render = render_json if args.format == "json" else render_text
    print(render(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
