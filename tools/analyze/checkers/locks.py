"""locks: shared mutable state must be read/written under its lock.

The serving tier shares one re-entrant memo lock
(``repro.core.memo.MEMO_LOCK``) across every costing-stack cache, and
the per-object locks of ``_SessionState`` / ``ScoringShardPool`` guard
their session/shard bookkeeping.  PR 4/8 established the discipline;
this checker makes it mechanical:

* inside a guarded class, every ``self.<field>`` access of a registered
  shared field must sit lexically inside ``with <lock>:``;
* the guarded module globals (``memo.REGISTRY``; devicecost's interning
  tables and shard-threshold state, writes only — their unlocked reads
  are deliberate CPython-safe fast paths) must be accessed under
  ``MEMO_LOCK``.

``__init__`` is exempt (no concurrent aliases exist yet).  A genuinely
safe unlocked access carries ``# lint: unlocked(<reason>)`` — the
reason is mandatory and shows up in review.

Scope is honest: dominance is *lexical* (a ``with`` in the same
function).  Helpers called only under a caller's lock document that
with a suppression, e.g. service.py's ``_engine_state``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from tools.analyze.core import Finding, ModuleRecord
from tools.analyze.dataflow import (build_parents, dotted,
                                    enclosing_function, under_lock)

NAME = "locks"

RULES = {
    "unlocked": "shared field/global accessed outside its lock",
}

#: spellings of the shared memo lock across modules
_MEMO_LOCKS = {"MEMO_LOCK", "memo.MEMO_LOCK", "memo_module.MEMO_LOCK"}

#: class name -> (lock spellings, guarded instance fields)
GUARDED_CLASSES: Dict[str, Dict] = {
    "DictCache": {"locks": _MEMO_LOCKS,
                  "fields": {"_data", "_hits", "_misses"}},
    "_SessionState": {"locks": {"self._lock"},
                      "fields": {"frontiers"}},
    "ScoringShardPool": {"locks": {"self._lock"},
                         "fields": {"_counters", "events", "_state",
                                    "_lost", "_epoch", "_pool"}},
    "DesignCalculatorService": {"locks": {"self._lock"},
                                "fields": {"_engine_health", "_sessions",
                                           "_stats"}},
}

#: guarded module-level globals: bare name -> config.  The bare-name rule
#: applies in the owner module and anywhere the name is imported from it;
#: the dotted spellings apply everywhere.
GUARDED_GLOBALS: Dict[str, Dict] = {
    "REGISTRY": {"owner": "repro.core.memo", "locks": _MEMO_LOCKS,
                 "writes_only": False,
                 "dotted": {"memo.REGISTRY", "memo_module.REGISTRY"}},
    "_MODEL_IDS": {"owner": "repro.core.devicecost", "locks": _MEMO_LOCKS,
                   "writes_only": True,
                   "dotted": {"devicecost._MODEL_IDS"}},
    "_MODEL_NAMES": {"owner": "repro.core.devicecost",
                     "locks": _MEMO_LOCKS, "writes_only": True,
                     "dotted": {"devicecost._MODEL_NAMES"}},
    "_SHARD_STATE": {"owner": "repro.core.devicecost",
                     "locks": _MEMO_LOCKS, "writes_only": True,
                     "dotted": {"devicecost._SHARD_STATE"}},
}

#: container-method calls that mutate the receiver
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popitem",
             "clear", "update", "setdefault", "move_to_end",
             "appendleft", "add", "discard"}


def _owner_module(relpath: str) -> str:
    """``src/repro/core/memo.py`` -> ``repro.core.memo`` (best effort)."""
    p = relpath[:-3] if relpath.endswith(".py") else relpath
    parts = p.split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts)


def _imported_from(tree: ast.Module) -> Dict[str, str]:
    """imported name -> source module, for ``from X import a, b``."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = node.module
    return out


def _is_write(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> bool:
    """Does this Name/Attribute access mutate the referenced object?

    Store/Del contexts, stores through a subscript (``X[k] = v``), and
    mutating method calls (``X.append(...)``) count as writes."""
    ctx = getattr(node, "ctx", None)
    if isinstance(ctx, (ast.Store, ast.Del)):
        return True
    parent = parents.get(node)
    if isinstance(parent, ast.Subscript) and parent.value is node and \
            isinstance(parent.ctx, (ast.Store, ast.Del)):
        return True
    if isinstance(parent, ast.Attribute) and parent.value is node and \
            parent.attr in _MUTATORS:
        grand = parents.get(parent)
        if isinstance(grand, ast.Call) and grand.func is parent:
            return True
    return False


def _check_class(cls: ast.ClassDef, cfg: Dict, mod: ModuleRecord,
                 parents: Dict[ast.AST, ast.AST]) -> Iterable[Finding]:
    fields: Set[str] = cfg["fields"]
    locks: Set[str] = cfg["locks"]
    for method in cls.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if method.name == "__init__":
            continue   # no concurrent aliases during construction
        for node in ast.walk(method):
            if not (isinstance(node, ast.Attribute)
                    and node.attr in fields
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                continue
            # nested defs (executor thunks, callbacks) are still methods
            # of the same object: the lock requirement stands
            if under_lock(node, parents, locks):
                continue
            kind = "write of" if _is_write(node, parents) else "read of"
            yield Finding(
                mod.relpath, node.lineno, NAME, "unlocked",
                f"{kind} {cls.name}.{node.attr} outside "
                f"'with {sorted(locks)[0]}:' in {method.name}()")


def _check_globals(mod: ModuleRecord,
                   parents: Dict[ast.AST, ast.AST]) -> Iterable[Finding]:
    imports = _imported_from(mod.tree)
    this_module = _owner_module(mod.relpath)
    active: Dict[str, Dict] = {}       # accessible spelling -> config
    for bare, cfg in GUARDED_GLOBALS.items():
        if this_module == cfg["owner"] or imports.get(bare) == cfg["owner"]:
            active[bare] = cfg
        owner_parent = ".".join(cfg["owner"].split(".")[:-1])
        for dotted_name in cfg["dotted"]:
            prefix = dotted_name.split(".")[0]
            if imports.get(prefix) == owner_parent \
                    or this_module == cfg["owner"]:
                active[dotted_name] = cfg
    if not active:
        return
    for node in ast.walk(mod.tree):
        name = None
        if isinstance(node, ast.Name) and node.id in active:
            name = node.id
        elif isinstance(node, ast.Attribute):
            d = dotted(node)
            if d in active:
                # skip inner Attribute of a longer guarded chain
                parent = parents.get(node)
                if isinstance(parent, ast.Attribute) and \
                        dotted(parent) in active:
                    continue
                name = d
        if name is None:
            continue
        cfg = active[name]
        if enclosing_function(node, parents) is None:
            continue   # module-level init runs before any concurrency
        if cfg["writes_only"] and not _is_write(node, parents):
            continue
        if under_lock(node, parents, cfg["locks"]):
            continue
        kind = "write of" if _is_write(node, parents) else "read of"
        yield Finding(
            mod.relpath, node.lineno, NAME, "unlocked",
            f"{kind} guarded global {name} outside 'with MEMO_LOCK:'")


def check_module(mod: ModuleRecord) -> Iterable[Finding]:
    parents = build_parents(mod.tree)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name in GUARDED_CLASSES:
            yield from _check_class(node, GUARDED_CLASSES[node.name],
                                    mod, parents)
    yield from _check_globals(mod, parents)
