"""jit-safety: jit/pmap-reachable code must honor the tracing contract.

The fused scorer's zero-recompile promise (devicecost, asserted via
``trace_count``) dies quietly when traced values leak into Python
control flow or host conversions.  For every function reachable from a
``jax.jit`` / ``jax.pmap`` binding *in the same module* (direct call,
decorator, or ``functools.partial`` form — partial-bound and
``static_argnums``/``static_argnames`` parameters are static):

* **traced-branch** — ``if`` / ``while`` / conditional expressions on a
  traced value: a ConcretizationTypeError at best, a silent per-value
  recompile at worst.  Branching on shape metadata is fine —
  ``x.shape`` / ``x.dtype`` / ``len(x)`` / ``jnp.issubdtype(...)``
  launder a traced value into static Python.
* **traced-concretize** — ``float()`` / ``int()`` / ``bool()`` /
  ``np.asarray()`` / ``.item()`` / ``.tolist()`` on a traced value:
  forces a device sync inside the trace or fails outright.
* **array-closure** — the jitted function closes over a module-level
  numpy/jax array that is reassigned somewhere, or is not a
  SCREAMING_CASE constant: closed-over arrays are baked into the
  compiled executable, so swapping them defeats the zero-recompile
  contract (pass them as arguments instead).  Frozen module constants
  (``DEFAULT_COEFFS``-style) are allowed.
* **unhashable-static** — a static parameter with an unhashable default
  (list/dict/set): ``jax.jit`` requires hashable statics.

Same-module helpers called with traced arguments are analyzed with
those parameters traced (memoized, cycle-safe) — the padding helpers in
the kernel wrappers get checked through their call sites.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from tools.analyze.core import Finding, ModuleRecord
from tools.analyze.dataflow import (Taint, call_keywords, const_int_tuple,
                                    const_str_tuple, dotted,
                                    module_functions, own_statements,
                                    param_names)

NAME = "jit-safety"

RULES = {
    "traced-branch": "Python control flow on a traced value",
    "traced-concretize": "host conversion of a traced value",
    "array-closure": "jitted function closes over a mutable module-level "
                     "array",
    "unhashable-static": "static jit parameter with an unhashable "
                         "default",
}

_JIT_CALLS = {"jax.jit", "jax.pmap", "pmap", "jit"}
_PARTIAL_CALLS = {"functools.partial", "partial"}
_CONCRETIZE_CALLS = {"float", "int", "bool", "np.asarray", "np.array",
                     "numpy.asarray", "numpy.array"}
_CONCRETIZE_ATTRS = {"item", "tolist"}
_ARRAY_PREFIXES = ("np.", "numpy.", "jnp.", "jax.numpy.")


def _positional_params(func: ast.FunctionDef) -> List[str]:
    a = func.args
    return [p.arg for p in a.posonlyargs + a.args]


def _static_names_from_call(call: ast.Call,
                            func: ast.FunctionDef) -> Set[str]:
    """Static parameter names from static_argnums/static_argnames."""
    out: Set[str] = set()
    kws = call_keywords(call)
    pos = _positional_params(func)
    nums = kws.get("static_argnums")
    if nums is not None:
        ints = const_int_tuple(nums)
        if ints:
            out.update(pos[i] for i in ints if 0 <= i < len(pos))
    names = kws.get("static_argnames")
    if names is not None:
        strs = const_str_tuple(names)
        if strs:
            out.update(strs)
    return out


def _jit_roots(tree: ast.Module) -> Dict[ast.FunctionDef, Set[str]]:
    """jit/pmap-bound same-module functions -> their static param names.

    Covers ``jax.jit(F, ...)`` / ``jax.pmap(F, ...)`` anywhere in the
    module (``F`` a module-level function name, possibly wrapped in
    ``functools.partial(F, **static_kwargs)``), plus the decorator forms
    ``@jax.jit`` and ``@functools.partial(jax.jit, ...)``.
    """
    funcs = module_functions(tree)
    roots: Dict[ast.FunctionDef, Set[str]] = {}

    def note(func: ast.FunctionDef, statics: Set[str]) -> None:
        roots.setdefault(func, set()).update(statics)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and dotted(node.func) in _JIT_CALLS \
                and node.args:
            target = node.args[0]
            if isinstance(target, ast.Name) and target.id in funcs:
                func = funcs[target.id]
                note(func, _static_names_from_call(node, func))
            elif isinstance(target, ast.Call) \
                    and dotted(target.func) in _PARTIAL_CALLS \
                    and target.args \
                    and isinstance(target.args[0], ast.Name) \
                    and target.args[0].id in funcs:
                func = funcs[target.args[0].id]
                statics = set(call_keywords(target))   # partial-bound kw
                statics |= _static_names_from_call(node, func)
                note(func, statics)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name not in funcs:
                continue
            for dec in node.decorator_list:
                if dotted(dec) in _JIT_CALLS:
                    note(node, set())
                elif isinstance(dec, ast.Call):
                    if dotted(dec.func) in _JIT_CALLS:
                        note(node, _static_names_from_call(dec, node))
                    elif dotted(dec.func) in _PARTIAL_CALLS and dec.args \
                            and dotted(dec.args[0]) in _JIT_CALLS:
                        note(node, _static_names_from_call(dec, node))
    return roots


def _module_arrays(tree: ast.Module) -> Dict[str, int]:
    """Module-level names bound to numpy/jax array expressions -> line."""
    out: Dict[str, int] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        is_array = any(
            isinstance(sub, ast.Call)
            and (dotted(sub.func) or "").startswith(_ARRAY_PREFIXES)
            for sub in ast.walk(node.value))
        if not is_array:
            continue
        for target in node.targets:
            if isinstance(target, ast.Name):
                out[target.id] = node.lineno
    return out


def _reassigned_names(tree: ast.Module) -> Set[str]:
    """Names stored anywhere below module top level (mutated state)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) \
                        and isinstance(sub.ctx, (ast.Store, ast.Del)):
                    out.add(sub.id)
    return out


class _Analyzer:
    def __init__(self, mod: ModuleRecord) -> None:
        self.mod = mod
        self.funcs = module_functions(mod.tree)
        self.arrays = _module_arrays(mod.tree)
        self.reassigned = _reassigned_names(mod.tree)
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, FrozenSet[str]]] = set()

    def analyze(self, func: ast.FunctionDef, traced: Set[str]) -> None:
        key = (func.name, frozenset(traced))
        if key in self._seen:
            return
        self._seen.add(key)
        taint = Taint(func, traced, sanitize_shapes=True)
        locals_ = set(param_names(func)) | {
            n.id for n in own_statements(func)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}
        for node in own_statements(func):
            if isinstance(node, (ast.If, ast.While)) \
                    and taint.expr_tainted(node.test):
                self._emit(node.lineno, "traced-branch",
                           f"Python {type(node).__name__.lower()} on a "
                           f"traced value in {func.name}() — branch on "
                           f"shape metadata or use jnp.where/lax.cond")
            elif isinstance(node, ast.IfExp) \
                    and taint.expr_tainted(node.test):
                self._emit(node.lineno, "traced-branch",
                           f"conditional expression on a traced value in "
                           f"{func.name}()")
            elif isinstance(node, ast.Call):
                self._check_call(node, func, taint)
            elif isinstance(node, ast.Name) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.id in self.arrays \
                    and node.id not in locals_:
                bad = node.id in self.reassigned \
                    or node.id != node.id.upper()
                if bad:
                    self._emit(node.lineno, "array-closure",
                               f"{func.name}() closes over module array "
                               f"{node.id!r} — closed-over arrays bake "
                               f"into the executable; pass it as an "
                               f"argument")

    def _check_call(self, node: ast.Call, func: ast.FunctionDef,
                    taint: Taint) -> None:
        callee = dotted(node.func)
        if callee in _CONCRETIZE_CALLS \
                and any(taint.expr_tainted(a) for a in node.args):
            self._emit(node.lineno, "traced-concretize",
                       f"{callee}() on a traced value in {func.name}() "
                       f"forces a host sync inside the trace")
            return
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _CONCRETIZE_ATTRS \
                and taint.expr_tainted(node.func.value):
            self._emit(node.lineno, "traced-concretize",
                       f".{node.func.attr}() on a traced value in "
                       f"{func.name}()")
            return
        # same-module helper called with traced arguments: descend
        if isinstance(node.func, ast.Name) and node.func.id in self.funcs:
            callee_func = self.funcs[node.func.id]
            if callee_func is func:
                return
            pos = _positional_params(callee_func)
            traced_params: Set[str] = set()
            for i, arg in enumerate(node.args):
                if i < len(pos) and taint.expr_tainted(arg):
                    traced_params.add(pos[i])
            for kw in node.keywords:
                if kw.arg and taint.expr_tainted(kw.value):
                    traced_params.add(kw.arg)
            if traced_params:
                self.analyze(callee_func, traced_params)

    def _emit(self, line: int, rule: str, message: str) -> None:
        self.findings.append(Finding(self.mod.relpath, line, NAME, rule,
                                     message))


def _check_static_defaults(func: ast.FunctionDef, statics: Set[str],
                           mod: ModuleRecord) -> Iterable[Finding]:
    a = func.args
    pos = a.posonlyargs + a.args
    defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    pairs = list(zip(pos, defaults)) + list(zip(a.kwonlyargs,
                                                a.kw_defaults))
    for param, default in pairs:
        if param.arg in statics and isinstance(
                default, (ast.List, ast.Dict, ast.Set)):
            yield Finding(
                mod.relpath, default.lineno, NAME, "unhashable-static",
                f"static jit parameter {param.arg!r} of {func.name}() "
                f"defaults to an unhashable "
                f"{type(default).__name__.lower()} — jax.jit requires "
                f"hashable statics (use a tuple)")


def check_module(mod: ModuleRecord) -> Iterable[Finding]:
    roots = _jit_roots(mod.tree)
    if not roots:
        return
    analyzer = _Analyzer(mod)
    for func, statics in roots.items():
        traced = {p for p in param_names(func) if p not in statics}
        analyzer.analyze(func, traced)
        yield from _check_static_defaults(func, statics, mod)
    yield from analyzer.findings
