"""cache-keys: no hardware in synthesis keys, no workload in statics keys.

The Data Calculator's zero-recompilation contract rests on two cache-key
purity invariants (docs/cost_pipeline.md, asserted at runtime by
tests/test_cache_keys.py):

* **hardware-in-key** — a :class:`HardwareProfile`-derived value must
  never reach the key of a registered synthesis/packing cache: packing
  is hardware-free by design, so re-costing a frontier on new hardware
  is a pure parameter-table swap.  The ``device_banks`` replica cache is
  the one deliberate exception (its values ARE per-device bank
  placements).
* **workload-in-key** — a workload-derived value must never reach the
  key of a *statics* cache (``chain_statics``, ``segment_statics``):
  statics are the workload-free template half, shared by every sweep
  point.

Statically: per function, parameters typed/named as hardware (resp.
workload) seed a taint fixpoint; the first argument of ``.get``/
``.put``/``.load`` on any module-level ``DictCache(name=...)`` variable
must not be tainted.  Note this is *stricter* than the runtime twin —
an ``int`` plucked off a workload still counts as workload-derived here
(route such values through an explicit parameter, the way
``chain_statics(chain, n_entries)`` does).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from tools.analyze.core import Finding, ModuleRecord
from tools.analyze.dataflow import (Taint, call_keywords, dotted,
                                    iter_functions, own_statements)

NAME = "cache-keys"

RULES = {
    "hardware-in-key": "HardwareProfile-derived value in a registered "
                       "synthesis/packing cache key",
    "workload-in-key": "workload-derived value in a template-statics "
                       "cache key",
}

#: registered caches whose keys ARE legitimately hardware-derived
HARDWARE_KEYED_OK = {"device_banks"}

#: registered caches holding workload-free template statics
#: (mirrors tests/test_cache_keys.py STATICS_CACHES)
STATICS_CACHES = {"chain_statics", "segment_statics"}

#: cache methods whose first argument is the key
KEYED_METHODS = {"get", "put", "load"}

_HW_PARAM_NAMES = {"hw", "hardware", "new_hw", "bulk_hw"}
_WL_PARAM_NAMES = {"workload", "workloads", "new_workload",
                   "base_workload"}


def _registered_caches(tree: ast.Module) -> Dict[str, str]:
    """Module-level ``VAR = DictCache(..., name="...")`` bindings:
    var name -> registered cache name (import aliases included — any
    constructor whose dotted name ends in ``DictCache`` counts)."""
    out: Dict[str, str] = {}
    for node in tree.body:
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        callee = dotted(node.value.func)
        if callee is None or not callee.split(".")[-1].endswith("DictCache"):
            continue
        name_kw = call_keywords(node.value).get("name")
        if not (isinstance(name_kw, ast.Constant)
                and isinstance(name_kw.value, str)):
            continue
        for target in node.targets:
            if isinstance(target, ast.Name):
                out[target.id] = name_kw.value
    return out


def _seed_params(func: ast.FunctionDef, type_suffixes: Set[str],
                 name_set: Set[str]) -> Set[str]:
    seeds: Set[str] = set()
    args = func.args
    for p in args.posonlyargs + args.args + args.kwonlyargs:
        ann = p.annotation
        ann_name = None
        if ann is not None:
            ann_name = dotted(ann)
            if ann_name is None and isinstance(ann, ast.Constant) \
                    and isinstance(ann.value, str):
                ann_name = ann.value
        if ann_name is not None and \
                ann_name.split(".")[-1] in type_suffixes:
            seeds.add(p.arg)
        elif p.arg in name_set:
            seeds.add(p.arg)
    return seeds


def check_module(mod: ModuleRecord) -> Iterable[Finding]:
    caches = _registered_caches(mod.tree)
    if not caches:
        return
    for func in iter_functions(mod.tree):
        hw_seeds = _seed_params(func, {"HardwareProfile"}, _HW_PARAM_NAMES)
        wl_seeds = _seed_params(func, {"Workload"}, _WL_PARAM_NAMES)
        if not hw_seeds and not wl_seeds:
            continue
        hw_taint = Taint(func, hw_seeds) if hw_seeds else None
        wl_taint = Taint(func, wl_seeds) if wl_seeds else None
        for node in own_statements(func):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in KEYED_METHODS
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in caches
                    and node.args):
                continue
            cache_name = caches[node.func.value.id]
            key_expr = node.args[0]
            if hw_taint is not None and cache_name not in HARDWARE_KEYED_OK \
                    and hw_taint.expr_tainted(key_expr):
                yield Finding(
                    mod.relpath, key_expr.lineno, NAME, "hardware-in-key",
                    f"hardware-derived value reaches the key of cache "
                    f"{cache_name!r} in {func.name}() — packing must stay "
                    f"hardware-free (zero-recompile contract)")
            if wl_taint is not None and cache_name in STATICS_CACHES \
                    and wl_taint.expr_tainted(key_expr):
                yield Finding(
                    mod.relpath, key_expr.lineno, NAME, "workload-in-key",
                    f"workload-derived value reaches the key of statics "
                    f"cache {cache_name!r} in {func.name}() — statics are "
                    f"shared across every sweep point")
