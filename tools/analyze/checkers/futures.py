"""futures: every submitted future must reach a bounded wait or escape.

The PR 8 leak class: a ``.result()`` with no timeout wedges a serving
thread forever when a fault (or a bug) keeps the future from resolving,
and a dropped ``executor.submit(...)`` return value leaks work that no
deadline, abort accounting or :func:`shards._abandon` path will ever
reclaim.  Statically, per function:

* **dropped-future** — a submit call used as a bare expression
  statement: nobody can ever wait on, cancel or account for it;
* **unawaited-future** — a variable bound to a submit call and then
  never mentioned again;
* **untimed-wait** — ``.result()`` with no timeout on a tracked future
  (chained ``submit(...).result()`` included).  Deliberately-blocking
  waits carry ``# lint: untimed-wait(<reason>)`` — e.g. the service's
  synchronous conveniences, whose futures are guaranteed to resolve by
  the worker supervisor or fail at ``stop()``.

Escapes count as handled: returning/yielding the future, passing it to
any call (``futures_wait``, ``_abandon``, callbacks), storing it in a
container or attribute, ``.cancel()`` / ``.add_done_callback()``.

Sources are ``X.submit(...)`` / ``X.submit_*(...)`` calls plus calls to
same-module functions (and same-class methods) that return such a call
— a per-module summary fixpoint, so ``shards._submit`` or a benchmark's
``_submit_interactive`` helper is tracked at its call sites too.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.analyze.core import Finding, ModuleRecord
from tools.analyze.dataflow import (build_parents, class_methods, dotted,
                                    iter_functions, module_functions,
                                    own_statements)

NAME = "futures"

RULES = {
    "dropped-future": "executor.submit(...) result discarded",
    "unawaited-future": "future assigned but never awaited, cancelled "
                        "or handed off",
    "untimed-wait": ".result() with no timeout on a submitted future",
}


def _submit_attr(call: ast.Call) -> bool:
    return (isinstance(call.func, ast.Attribute)
            and (call.func.attr == "submit"
                 or call.func.attr.startswith("submit_")))


class _ModuleIndex:
    """Per-module summary: which local functions return futures."""

    def __init__(self, tree: ast.Module) -> None:
        self.mod_funcs = module_functions(tree)
        self.methods: Dict[str, Dict[str, ast.FunctionDef]] = {}
        self.owner_class: Dict[ast.FunctionDef, str] = {}
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                ms = class_methods(node)
                self.methods[node.name] = ms
                for m in ms.values():
                    self.owner_class[m] = node.name
        self.future_funcs: Set[str] = set()          # module-level names
        self.future_methods: Set[Tuple[str, str]] = set()  # (class, meth)
        self._summarize()

    def is_source(self, call: ast.Call,
                  func: Optional[ast.FunctionDef]) -> bool:
        if _submit_attr(call):
            return True
        if isinstance(call.func, ast.Name) \
                and call.func.id in self.future_funcs:
            return True
        if isinstance(call.func, ast.Attribute) \
                and isinstance(call.func.value, ast.Name) \
                and call.func.value.id == "self" and func is not None:
            cls = self.owner_class.get(func)
            if cls and (cls, call.func.attr) in self.future_methods:
                return True
        return False

    def _returns_source(self, func: ast.FunctionDef) -> bool:
        tracked = _tracked_names(func, self)
        for node in own_statements(func):
            if isinstance(node, ast.Return) and node.value is not None:
                v = node.value
                if isinstance(v, ast.Call) and self.is_source(v, func):
                    return True
                if isinstance(v, ast.Name) and v.id in tracked:
                    return True
        return False

    def _summarize(self) -> None:
        changed = True
        while changed:
            changed = False
            for name, func in self.mod_funcs.items():
                if name not in self.future_funcs \
                        and self._returns_source(func):
                    self.future_funcs.add(name)
                    changed = True
            for cls, ms in self.methods.items():
                for name, func in ms.items():
                    key = (cls, name)
                    if key not in self.future_methods \
                            and self._returns_source(func):
                        self.future_methods.add(key)
                        changed = True


def _tracked_names(func: ast.FunctionDef, index: _ModuleIndex) -> Set[str]:
    """Local names bound directly to a future source (``f = X.submit(..)``).

    Container vars of futures (list literals / comprehensions of sources,
    ``fs.append(source)``) are tracked separately by the caller."""
    out: Set[str] = set()
    for node in own_statements(func):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and index.is_source(node.value, func):
            out.add(node.targets[0].id)
    return out


def _container_names(func: ast.FunctionDef, index: _ModuleIndex
                     ) -> Set[str]:
    """Local names holding a list/set of future sources."""
    out: Set[str] = set()
    for node in own_statements(func):
        # fs = [source(...) for ...] / {source(...) for ...} / [source, ..]
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            v = node.value
            elt = None
            if isinstance(v, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
                elt = v.elt
            if elt is not None and isinstance(elt, ast.Call) \
                    and index.is_source(elt, func):
                out.add(node.targets[0].id)
            if isinstance(v, (ast.List, ast.Set, ast.Tuple)) and v.elts \
                    and all(isinstance(e, ast.Call)
                            and index.is_source(e, func) for e in v.elts):
                out.add(node.targets[0].id)
        # fs.append(source(...))
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("append", "add") \
                and isinstance(node.func.value, ast.Name) and node.args \
                and isinstance(node.args[0], ast.Call) \
                and index.is_source(node.args[0], func):
            out.add(node.func.value.id)
    return out


def _itervars(func: ast.FunctionDef, containers: Set[str]) -> Set[str]:
    """Loop/comprehension variables iterating a future container."""
    out: Set[str] = set()
    for node in own_statements(func):
        iters: List[Tuple[ast.expr, ast.expr]] = []
        if isinstance(node, ast.For):
            iters.append((node.target, node.iter))
        elif isinstance(node, ast.comprehension):
            iters.append((node.target, node.iter))
        for target, it in iters:
            if isinstance(it, ast.Name) and it.id in containers \
                    and isinstance(target, ast.Name):
                out.add(target.id)
    return out


def _has_timeout(call: ast.Call) -> bool:
    return bool(call.args) or any(kw.arg == "timeout"
                                  for kw in call.keywords)


def check_module(mod: ModuleRecord) -> Iterable[Finding]:
    index = _ModuleIndex(mod.tree)
    for func in iter_functions(mod.tree):
        parents = build_parents(func)
        tracked = _tracked_names(func, index)
        containers = _container_names(func, index)
        futureish = tracked | _itervars(func, containers)

        for node in own_statements(func):
            # 1. bare `X.submit(...)` expression statement
            if isinstance(node, ast.Expr) \
                    and isinstance(node.value, ast.Call) \
                    and index.is_source(node.value, func):
                yield Finding(
                    mod.relpath, node.lineno, NAME, "dropped-future",
                    f"submit result discarded in {func.name}() — nothing "
                    f"can wait on, cancel or account for this future")
                continue
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            # 2. chained `<source>(...).result(...)` and `fut.result(...)`
            if isinstance(f, ast.Attribute) and f.attr == "result":
                recv = f.value
                chained = isinstance(recv, ast.Call) \
                    and index.is_source(recv, func)
                named = isinstance(recv, ast.Name) and recv.id in futureish
                if (chained or named) and not _has_timeout(node):
                    yield Finding(
                        mod.relpath, node.lineno, NAME, "untimed-wait",
                        f".result() with no timeout in {func.name}() — an "
                        f"unresolved future wedges this thread forever "
                        f"(pass timeout=, or suppress with a documented "
                        f"'# lint: untimed-wait(...)')")

        # 3. tracked futures that are never used at all
        for name in tracked:
            uses = [n for n in own_statements(func)
                    if isinstance(n, ast.Name) and n.id == name
                    and isinstance(n.ctx, ast.Load)]
            if not uses:
                assigns = [n for n in own_statements(func)
                           if isinstance(n, ast.Name) and n.id == name
                           and isinstance(n.ctx, ast.Store)]
                line = min((n.lineno for n in assigns),
                           default=func.lineno)
                yield Finding(
                    mod.relpath, line, NAME, "unawaited-future",
                    f"future {name!r} in {func.name}() is never awaited, "
                    f"cancelled or handed off (the PR 8 leak class)")
