"""Checker registry: every repro-lint checker module, in report order."""
from tools.analyze.checkers import (cache_keys, docs_refs, futures,
                                    jit_safety, locks)

ALL_CHECKERS = [cache_keys, locks, futures, jit_safety, docs_refs]

#: NAME -> module, for --checker filtering
BY_NAME = {c.NAME: c for c in ALL_CHECKERS}
