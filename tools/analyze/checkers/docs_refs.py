"""docs-refs: documentation references must resolve, or the lint fails.

The framework fold-in of ``tools/check_docs.py`` (which remains as a
thin CLI shim): scans ``README.md`` and ``docs/*.md`` for

* dotted code references (``repro.core.batchcost.pack_sweep``,
  ``tools.analyze`` ...) — each must import and, where it names an
  attribute, resolve via ``getattr``;
* repo-relative file paths (``src/repro/core/whatif.py`` ...) — each
  must exist.

Repo-scope: runs once per invocation regardless of the analyzed paths.
"""
from __future__ import annotations

import glob
import importlib
import os
import re
import sys
from typing import Iterable, List

from tools.analyze.core import ROOT, Finding

NAME = "docs-refs"

RULES = {
    "stale-ref": "documentation references a module/attribute/path that "
                 "no longer resolves",
}

for _p in (os.path.join(ROOT, "src"), ROOT):   # repro.* and benchmarks.*
    if _p not in sys.path:
        sys.path.insert(0, _p)

#: dotted module/attribute references worth auditing
_DOTTED = re.compile(r"\b(?:repro|benchmarks|tools)(?:\.[A-Za-z_]\w*)+")
#: repo-relative paths under the directories docs talk about
_PATHISH = re.compile(
    r"\b(?:src|docs|tests|tools|benchmarks|examples|experiments)"
    r"/[\w][\w./-]*")


def doc_files() -> List[str]:
    return [os.path.join(ROOT, "README.md")] + \
        sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))


def resolve_dotted(ref: str):
    """None when ``ref`` imports/getattrs cleanly, else the error."""
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        for attr in parts[cut:]:
            try:
                obj = getattr(obj, attr)
            except AttributeError:
                return (f"{ref}: module {modname!r} has no attribute "
                        f"{'.'.join(parts[cut:])!r}")
        return None
    return f"{ref}: no importable module prefix"


def check_doc_texts(files: List[str]) -> List[str]:
    """Error strings for every stale reference in ``files`` (the legacy
    ``check_docs`` contract the tools/check_docs.py shim preserves)."""
    errors: List[str] = []
    for path in files:
        rel = os.path.relpath(path, ROOT)
        if not os.path.exists(path):
            errors.append(f"{rel}: file is missing")
            continue
        with open(path) as fh:
            text = fh.read()
        for ref in sorted(set(_DOTTED.findall(text))):
            err = resolve_dotted(ref)
            if err is not None:
                errors.append(f"{rel}: {err}")
        for p in sorted(set(_PATHISH.findall(text))):
            p = p.rstrip(".,:;")    # sentence punctuation
            if not os.path.exists(os.path.join(ROOT, p)):
                errors.append(f"{rel}: referenced path {p!r} does not "
                              f"exist")
    return errors


def _anchor_line(path: str, needle: str) -> int:
    """First line mentioning ``needle`` (0 when the file is unreadable)."""
    try:
        with open(path) as fh:
            for lineno, line in enumerate(fh, start=1):
                if needle in line:
                    return lineno
    except OSError:
        pass
    return 0


def check_repo(root: str) -> Iterable[Finding]:
    for path in doc_files():
        rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
        for err in check_doc_texts([path]):
            msg = err.split(": ", 1)[1] if ": " in err else err
            needle = msg.split(":")[0].strip().strip("'\"")
            yield Finding(rel, _anchor_line(path, needle), NAME,
                          "stale-ref", msg)
