#!/usr/bin/env python
"""Tiny docs checker: documentation references must resolve, or CI fails.

Scans ``README.md`` and ``docs/*.md`` for

* dotted code references (``repro.core.batchcost.pack_sweep``,
  ``benchmarks.search_bench`` ...) — each must import and, where it names
  an attribute, resolve via ``getattr``;
* repo-relative file paths (``src/repro/core/whatif.py``,
  ``experiments/bench/BENCH_search.json`` ...) — each must exist.

So a rename or deletion that would silently rot the docs instead fails
``tests/test_docs.py`` (and this script, runnable standalone):

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import glob
import importlib
import os
import re
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(ROOT, "src"), ROOT):   # repro.* and benchmarks.*
    if p not in sys.path:
        sys.path.insert(0, p)

#: dotted module/attribute references worth auditing
_DOTTED = re.compile(r"\b(?:repro|benchmarks|tools)(?:\.[A-Za-z_]\w*)+")
#: repo-relative paths under the directories docs talk about
_PATHISH = re.compile(
    r"\b(?:src|docs|tests|tools|benchmarks|examples|experiments)"
    r"/[\w][\w./-]*")


def doc_files() -> List[str]:
    return [os.path.join(ROOT, "README.md")] + \
        sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))


def _resolve_dotted(ref: str):
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        for attr in parts[cut:]:
            try:
                obj = getattr(obj, attr)
            except AttributeError:
                return (f"{ref}: module {modname!r} has no attribute "
                        f"{'.'.join(parts[cut:])!r}")
        return None
    return f"{ref}: no importable module prefix"


def check_docs() -> List[str]:
    errors: List[str] = []
    for path in doc_files():
        rel = os.path.relpath(path, ROOT)
        if not os.path.exists(path):
            errors.append(f"{rel}: file is missing")
            continue
        with open(path) as fh:
            text = fh.read()
        for ref in sorted(set(_DOTTED.findall(text))):
            err = _resolve_dotted(ref)
            if err is not None:
                errors.append(f"{rel}: {err}")
        for p in sorted(set(_PATHISH.findall(text))):
            p = p.rstrip(".,:;")    # sentence punctuation
            if not os.path.exists(os.path.join(ROOT, p)):
                errors.append(f"{rel}: referenced path {p!r} does not "
                              f"exist")
    return errors


def main() -> int:
    errors = check_docs()
    for err in errors:
        print(f"docs-check: {err}", file=sys.stderr)
    print(f"docs-check: scanned {len(doc_files())} files, "
          f"{len(errors)} stale references")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
