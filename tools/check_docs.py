#!/usr/bin/env python
"""Docs checker CLI — now a thin shim over the repro-lint framework.

The actual scanning lives in ``tools.analyze.checkers.docs_refs`` (the
``docs-refs`` checker, run as part of ``python -m tools.analyze``).
This entry point keeps the historical interface working:

    PYTHONPATH=src python tools/check_docs.py

``doc_files`` / ``check_docs`` keep their old signatures so existing
callers (and tests/test_docs.py) are unaffected.
"""
from __future__ import annotations

import os
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(ROOT, "src"), ROOT):   # repro.* and benchmarks.*
    if p not in sys.path:
        sys.path.insert(0, p)

from tools.analyze.checkers import docs_refs as _docs_refs

_DOTTED = _docs_refs._DOTTED
_PATHISH = _docs_refs._PATHISH


def doc_files() -> List[str]:
    return _docs_refs.doc_files()


def _resolve_dotted(ref: str):
    return _docs_refs.resolve_dotted(ref)


def check_docs() -> List[str]:
    return _docs_refs.check_doc_texts(doc_files())


def main() -> int:
    errors = check_docs()
    for err in errors:
        print(f"docs-check: {err}", file=sys.stderr)
    print(f"docs-check: scanned {len(doc_files())} files, "
          f"{len(errors)} stale references")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
