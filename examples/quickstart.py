"""Quickstart: cost a data structure design without implementing it.

    PYTHONPATH=src python examples/quickstart.py

Covers the Calculator loop end to end: describe a design as layout
primitives -> synthesize the Get operation -> price it on two hardware
profiles -> read the per-primitive breakdown (paper Fig. 2 / §3).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import elements as el
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload, synthesize_get

# 1. a design: classic B+tree (fanout-20 internals, 250-record sorted leaves)
spec = el.spec_btree(fanout=20, page=250)
print(f"design: {spec.describe()}")

# 2. a workload: 100k uniform keys, 100 point Gets
workload = Workload(n_entries=100_000, n_queries=100)

# 3. synthesize the Get operation -> Level-1 access primitive sequence
breakdown = synthesize_get(spec, workload)
print(f"synthesized access path: {breakdown.format()}")
print("  (compare paper §3: P(312)+B(152)+P(6552)+B(152)+P(1606552)+"
      "B(2000)+P(2000))")

# 4. price it on two machines — no implementation, no deployment
for hw in (hw1(), hw3()):
    latency = breakdown.total(hw)
    print(f"predicted Get latency on {hw.name}: {latency * 1e6:.3f} us")

# 5. one what-if: would bloom filters on the leaves help here?
from repro.core import whatif
answer = whatif.what_if_design(spec, whatif.add_bloom_filters(spec),
                               workload, hw1())
print(answer.summary())
