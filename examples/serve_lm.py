"""Batched serving example (deliverable b): prefill + decode a batch of
requests through the jitted serve step with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b
    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b

Works for every assigned architecture family (KV caches for attention
archs, constant-size recurrent state for xlstm/zamba2).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size,
        (args.requests, args.prompt_len)).astype(np.int32)
    out = serve_batch(cfg, prompts, args.max_new)
    print(f"[{args.arch}] generated {out['tokens'].shape[1]} tokens for "
          f"{out['tokens'].shape[0]} requests")


if __name__ == "__main__":
    main()
