"""The §5 'Rich Design Questions' session, replayed.

    PYTHONPATH=src python examples/whatif_design.py

A user operating a B-tree design asks the Calculator a sequence of
design / hardware / workload questions; every answer is a cost synthesis,
not an experiment.
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import elements as el, whatif
from repro.core.autocomplete import complete_design
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload

workload = Workload(n_entries=1_000_000, n_queries=100)
base = el.spec_btree()

print("Q1: What if we change our hardware to HW3?")
print("   ", whatif.what_if_hardware(base, workload, hw1(), hw3()).summary())

print("Q2: Is there a better design for HW3 and this workload?")
result = complete_design((), workload, hw3(), mix={"get": 100.0},
                         max_depth=2)
print("   ", result.summary())

print("Q3: Would bloom filters in all B-tree leaves help?")
print("   ", whatif.what_if_design(
    base, whatif.add_bloom_filters(base), workload, hw3()).summary())

print("Q4: What if the workload skews to 0.01% of the key space?")
skewed = dataclasses.replace(workload, zipf_alpha=2.0)
print("   ", whatif.what_if_workload(base, workload, skewed,
                                     hw3()).summary())

print("Q5: ...and is there a better design for that skewed workload?")
result = complete_design((), skewed, hw3(), mix={"get": 100.0}, max_depth=2)
print("   ", result.summary())

print("Q6: And across the whole skew axis 0.0 -> 2.0 at once?")
axis = [dataclasses.replace(workload, zipf_alpha=a)
        for a in (0.0, 0.5, 1.0, 1.5, 2.0)]
sweep = whatif.workload_sweep([base, whatif.add_bloom_filters(base)],
                              axis, hw3())
print("   ", sweep.summary().replace("\n", "\n    "))
