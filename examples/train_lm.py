"""End-to-end training driver (deliverable b): train a small LM for a few
hundred steps on this host with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py            # ~20M params
    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 512

Uses the production launcher (repro.launch.train) — the same code path
the dry-run proves at (2,16,16); here the mesh is the single host device.
Interrupt it and re-run: training resumes from the last checkpoint.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ArchConfig(
        arch_id="train-lm-demo", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab_size=8192,
        param_dtype="float32", remat=False)
    n_params = cfg.n_params()
    print(f"model: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps of [{args.batch} x {args.seq}]")

    run = RunConfig(learning_rate=1e-3, total_steps=args.steps,
                    warmup_steps=max(args.steps // 10, 1),
                    checkpoint_dir=args.ckpt, checkpoint_every=50,
                    log_every=10)
    shape = ShapeConfig("demo", args.seq, args.batch, "train")
    out = train(cfg, shape, run)
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} over "
          f"{out['steps']} steps; health: {out['health']}")
    assert out["final_loss"] < out["first_loss"], "training must converge"


if __name__ == "__main__":
    main()
