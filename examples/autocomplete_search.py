"""Fig. 9: the Calculator designs hybrid structures to fit a workload.

    PYTHONPATH=src python examples/autocomplete_search.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autocomplete import DomainRegion, design_hybrid
from repro.core.hardware import hw3
from repro.core.synthesis import Workload

workload = Workload(n_entries=1_000_000)

print("Scenario 1: point reads on 20% of the domain, writes on the rest")
design = design_hybrid(workload, [
    DomainRegion("point-reads", 0.2, {"get": 100.0}),
    DomainRegion("writes", 0.8, {"update": 100.0, "bulk_load": 1.0}),
], hw3())
print("  ", design.describe())
print(f"   cost {design.cost_seconds:.3e}s, designed in "
      f"{design.elapsed_seconds:.1f}s")

print("Scenario 2: + disjoint range-read region")
design = design_hybrid(workload, [
    DomainRegion("point-reads", 0.1, {"get": 50.0}),
    DomainRegion("range-reads", 0.1, {"range_get": 50.0}),
    DomainRegion("writes", 0.8, {"update": 100.0, "bulk_load": 1.0}),
], hw3())
print("  ", design.describe())
print(f"   cost {design.cost_seconds:.3e}s, designed in "
      f"{design.elapsed_seconds:.1f}s")
