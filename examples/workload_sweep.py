"""The design continuum, one fused question: best design vs read fraction.

    PYTHONPATH=src python examples/workload_sweep.py

A designer asks "as my workload shifts from write-heavy to read-heavy,
when does the best data structure change — and what does the crossover
cost?".  Pre-PR-5 this was one auto-completion per sweep point (each
re-deriving the same chains' geometry); now the whole
(designs x workloads) grid packs shared template statics once and scores
in ONE fused call (`workload_sweep` / `design_continuum`).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import whatif
from repro.core.autocomplete import design_continuum, enumerate_frontier
from repro.core.hardware import hw3
from repro.core.synthesis import Workload

workload = Workload(n_entries=1_000_000, n_queries=100)
fractions = [i / 10 for i in range(11)]           # read fraction 0.0 -> 1.0
mixes = whatif.read_fraction_mixes(fractions)
workloads = [workload] * len(fractions)

print("Q: how does the best design change with the read fraction?")
results = design_continuum((), workloads, hw3(), mixes=mixes, max_depth=2)
print(f"   {results[0].explored} candidate designs x "
      f"{len(fractions)} workload points, "
      f"answered in {results[0].elapsed_seconds:.2f}s\n")

print(f"{'read%':>6}  {'best design':<42} {'cost/op':>11}")
for f, r in zip(fractions, results):
    print(f"{f * 100:5.0f}%  {r.spec.describe():<42} "
          f"{r.cost_seconds:10.3e}s")

# The full grid is one call too — chart the continuum of a few named
# designs against the winner (an ASCII "plot"; totals[w, d]).
specs = list(enumerate_frontier((), max_depth=2, name="sweep-example"))
answer = whatif.workload_sweep(specs, workloads, hw3(), mixes)
best = answer.totals.min(axis=1)
print("\ncheapest-design cost across the axis (normalized bar):")
for f, b in zip(fractions, best):
    bar = "#" * max(int(round(40 * b / best.max())), 1)
    print(f"{f * 100:5.0f}%  {bar:<42} {b:9.3e}s")

switches = [i for i in range(1, len(results))
            if results[i].spec.describe() != results[i - 1].spec.describe()]
if switches:
    for i in switches:
        print(f"\ncrossover at read fraction {fractions[i]:.1f}: "
              f"{results[i - 1].spec.describe()} -> "
              f"{results[i].spec.describe()}")
else:
    print(f"\nno crossover: {results[0].spec.describe()} wins the "
          f"whole axis")
print(f"grid shape {answer.totals.shape}, "
      f"argmin parity with np.argmin: "
      f"{bool((answer.best_indices == np.argmin(answer.totals, 1)).all())}")
