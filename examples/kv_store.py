"""The paper's data structures as JAX/TPU-native stores, built on the
Pallas access-primitive kernels (cross-pollination, §3 'Extensibility').

    PYTHONPATH=src python examples/kv_store.py

Three designs from the element library, each served by the TPU Level-2
kernels instead of the CPU Level-2 implementations:
  sorted array   -> sorted_search kernel (compare-count bisection)
  hash table     -> hash_probe kernel (multiply-shift, bucket compare)
  log + bloom    -> bloom_probe kernel skips the scan_filter kernel
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom_probe.ops import DEFAULT_COEFFS, bloom_probe
from repro.kernels.bloom_probe.ref import build_filter
from repro.kernels.hash_probe.ops import DEFAULT_A, hash_probe
from repro.kernels.hash_probe.ref import build_table
from repro.kernels.scan_filter.ops import scan_get
from repro.kernels.sorted_search.ops import sorted_get

rng = np.random.default_rng(0)
N, Q = 20_000, 512
keys = rng.choice(1 << 24, N, replace=False).astype(np.int64)
values = rng.integers(1, 1 << 30, N).astype(np.int32)
queries = np.concatenate([keys[: Q // 2],
                          rng.integers(1 << 25, 1 << 26, Q // 2)])
queries = queries.astype(np.int32)
expected_hits = Q // 2

print(f"store: {N} keys; probing {Q} queries ({expected_hits} present)")

# --- sorted array (ODP terminal; Sorted Search Level-2) --------------------
order = np.argsort(keys)
t0 = time.perf_counter()
found, val = sorted_get(jnp.asarray(keys[order].astype(np.int32)),
                        jnp.asarray(values[order]), jnp.asarray(queries))
hits = int(np.asarray(found).sum())
print(f"sorted-array store: {hits}/{expected_hits} hits   "
      f"({time.perf_counter() - t0:.2f}s interpret mode)")
assert hits == expected_hits

# --- hash table (Hash -> fixed-cap buckets; Hash Probe Level-2) -------------
s_bits = 11
tk, tv = build_table(keys, values, s_bits, DEFAULT_A, cap=32)
t0 = time.perf_counter()
found, val = hash_probe(jnp.asarray(tk), jnp.asarray(tv),
                        jnp.asarray(queries), s=s_bits)
hits = int(np.asarray(found).sum())
print(f"hash-table store:   {hits}/{expected_hits} hits   "
      f"({time.perf_counter() - t0:.2f}s)")
assert hits == expected_hits

# --- log with bloom filter (UDP + bloom; Bloom Probe skips Scan) -----------
s_filter = 18
words = build_filter(keys, DEFAULT_COEFFS[:3], s_filter)
t0 = time.perf_counter()
maybe = np.asarray(bloom_probe(jnp.asarray(words), jnp.asarray(queries),
                               s=s_filter, num_hashes=3))
skipped = int((~maybe).sum())
probe_queries = queries[maybe]
found, val = scan_get(jnp.asarray(keys.astype(np.int32)),
                      jnp.asarray(values), jnp.asarray(probe_queries))
hits = int(np.asarray(found).sum())
print(f"log+bloom store:    {hits}/{expected_hits} hits, bloom skipped "
      f"{skipped}/{Q - expected_hits} misses "
      f"({time.perf_counter() - t0:.2f}s)")
assert hits == expected_hits
print("all stores agree with the oracle")
